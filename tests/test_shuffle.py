"""Equivalence + invariant gate for the event-driven shuffle substrate
(DESIGN.md §12.3) and the batched macro-event fetch plane (§14).

Three layers, mirroring the columnar gate of ``tests/test_columnar.py``:

1. **Trace equivalence** — seeded simulations under crash / delay /
   MOF-loss faults must behave byte-identically whether fetch candidates
   come from the indexed ready-queues (``shuffle="event"``), the seed's
   poll-and-rescan path (``shuffle="rescan"``), or the calendar-lane
   batch plane (``shuffle="batch"``): same speculator action traces,
   same attempt launches (task, node, reason, time), same job results —
   including the Hadoop too-many-fetch-failures quorum re-run. (The
   random-script differential matrix lives in
   tests/test_fuzz_equivalence.py.)
2. **Dependency-status partition** (hypothesis) — under random
   crash/delay/MOF fault schedules, every dependency of every running
   reduce attempt is in exactly one of {waiting, ready, inflight,
   fail-cycle, fetched}, each status bucket matches its side structure,
   and the MOF registry equals a from-scratch recomputation.
3. Unit behaviours of the MOF registry and the shuffle profile counters.
"""
import pytest

from conftest import (
    HAVE_HYPOTHESIS,
    check_invariants as _check_invariants_impl,
    result_key as _result_key,
    run_traced,
)
from repro.core.types import AttemptState, TaskKind, TaskState
from repro.sim import JobSpec, Simulation, faults

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------
def _crash(sim, job):
    faults.crash_busiest_node_at_map_progress(sim, job, 0.4)


def _crash_late(sim, job):
    # Crash once the shuffle is in full swing: reduce attempts on the
    # crashed host keep running with silently-aborted fetches (free
    # budget + ready producers) and must be re-kicked by the next
    # completion exactly like the rescan broadcast does — the regression
    # that motivated EventShuffle's stalled set.
    faults.crash_busiest_node_at_map_progress(sim, job, 1.0)


def _crash_very_late(sim, job):
    faults.crash_busiest_node_at_map_progress(sim, job, 0.98)


def _crash_restore(sim, job):
    faults.crash_busiest_node_at_map_progress(sim, job, 0.3,
                                              restore_after=90.0)


def _delay(sim, job):
    def fire():
        counts = {}
        for t in job.maps:
            for a in t.running_attempts():
                counts[a.node_id] = counts.get(a.node_id, 0) + 1
        victim = max(sorted(counts), key=lambda n: counts[n]) \
            if counts else sim.cluster.node_ids[0]
        sim.set_node_speed(victim, 0.05)
        sim.engine.after(150.0, sim.set_node_speed, victim, 1.0)
    sim.engine.at(30.0, fire)


def _mof(sim, job):
    faults.lose_mof_at_map_progress(sim, job, 1.0)


def _mof_wide(sim, job):
    # Quorum scenario: allow victims many running reducers still need, so
    # fetch-failure reports stack up past max(3, 0.5 × running reduces)
    # and the AM gives up on the MOF (the "am-fetch-failures" re-run).
    faults.lose_mof_at_map_progress(sim, job, 1.0, max_stragglers=16)


def _run(mode, policy, fault, seed=1, bench="terasort", gb=2.0,
         n_reduces=None, extra_jobs=(), checks=None):
    r = run_traced(mode, policy, fault, seed=seed, bench=bench, gb=gb,
                   n_reduces=n_reduces, extra_jobs=extra_jobs,
                   checks=checks)
    return r.sim, r.job, r.launches, r.results


def _assert_equivalent(policy, fault, seed=1, bench="terasort", gb=2.0,
                       n_reduces=None, extra_jobs=()):
    """rescan / event / batch must agree byte for byte; returns the
    event run for scenario-shape assertions."""
    ev, _, ev_launch, ev_res = _run("event", policy, fault, seed, bench,
                                    gb, n_reduces, extra_jobs)
    for mode in ("rescan", "batch"):
        om, _, om_launch, om_res = _run(mode, policy, fault, seed, bench,
                                        gb, n_reduces, extra_jobs)
        assert ev.action_trace == om.action_trace, mode
        assert ev_launch == om_launch, mode
        assert _result_key(ev_res) == _result_key(om_res), mode
    assert ev_launch, "scenario launched nothing — not probing"
    return ev, ev_launch


def _check_invariants(sim):
    _check_invariants_impl(sim)


# ---------------------------------------------------------------------------
# 1. Event vs rescan trace equivalence on seeded faulted runs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["yarn", "bino"])
@pytest.mark.parametrize("fault,seed", [
    (_crash, 1), (_delay, 1), (_mof, 2), (_crash_restore, 3)])
def test_engines_identical_under_faults(policy, fault, seed):
    _assert_equivalent(policy, fault, seed=seed)


@pytest.mark.parametrize("policy", ["yarn", "bino"])
@pytest.mark.parametrize("fault", [_crash_late, _crash_very_late])
def test_engines_identical_under_late_crash(policy, fault):
    # seed=3 / 4 GB is the exact configuration that exposed the stalled-
    # attempt divergence (zombie reducers on the crashed host were never
    # re-kicked by the subscriber registry).
    _assert_equivalent(policy, fault, seed=3, gb=4.0)


def test_engines_identical_multi_job():
    extra = (JobSpec("j1", "wordcount", 1.0, submit_time=20.0),
             JobSpec("j2", "grep", 1.0, submit_time=35.0))
    _assert_equivalent("bino", _delay, seed=3, bench="aggregation",
                       extra_jobs=extra)


@pytest.mark.parametrize("policy", ["yarn", "bino"])
def test_fetch_failure_quorum_rerun_equivalence(policy):
    """The dependency-oblivious stall itself: a widely-needed MOF vanishes,
    reducers burn fetch cycles, reports pass the AM quorum and the map
    re-runs — byte-identically under both engines."""
    sim, launches = _assert_equivalent(policy, _mof_wide, seed=2,
                                       n_reduces=8)
    reasons = {reason for _, _, _, reason, _, _ in launches}
    assert "am-fetch-failures" in reasons, reasons
    assert sim.jobs["j0"].n_fetch_failures > 0


def test_invariants_hold_through_faulted_runs():
    for fault in (_crash_restore, _mof, _delay):
        _run("event", "bino", fault, seed=1,
             checks=range(10, 900, 17))


# ---------------------------------------------------------------------------
# 2. Hypothesis: dependency-status partition under random fault schedules
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _fault_step = st.tuples(
        st.sampled_from(["crash", "crash_restore", "delay", "mof", "hb"]),
        st.integers(0, 19),           # victim node index
        st.floats(0.05, 0.95))        # progress fraction / time scale

    @given(schedule=st.lists(_fault_step, min_size=1, max_size=3),
           seed=st.integers(0, 7))
    @settings(max_examples=15, deadline=None)
    def test_dependency_partition_under_random_faults(schedule, seed):
        sim = Simulation(policy="bino", seed=seed, shuffle="event")
        job = sim.submit(JobSpec("j0", "terasort", 1.0))
        for kind, idx, x in schedule:
            nid = sim.cluster.node_ids[idx]
            at = 15.0 + x * 180.0
            if kind == "crash":
                faults.crash_node_at(sim, nid, at)
            elif kind == "crash_restore":
                faults.crash_node_at(sim, nid, at, restore_after=75.0)
            elif kind == "delay":
                faults.slow_node_at(sim, nid, at, 0.05, duration=120.0)
            elif kind == "mof":
                faults.lose_mof_at_map_progress(sim, job, x)
            else:
                faults.heartbeat_outage_at(sim, nid, at, 30.0)
        for t in range(5, 1100, 13):
            sim.engine.at(float(t), _check_invariants, sim)
        sim.run()
        # the partition must also hold at the end state
        _check_invariants(sim)

    @given(fault=st.sampled_from(["crash", "delay", "mof"]),
           seed=st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_engines_equivalent_random_seeds(fault, seed):
        fn = {"crash": _crash, "delay": _delay, "mof": _mof}[fault]
        ev, _, ev_launch, ev_res = _run("event", "bino", fn, seed=seed,
                                        gb=1.0)
        rs, _, rs_launch, rs_res = _run("rescan", "bino", fn, seed=seed,
                                        gb=1.0)
        assert ev.action_trace == rs.action_trace
        assert ev_launch == rs_launch
        assert _result_key(ev_res) == _result_key(rs_res)


# ---------------------------------------------------------------------------
# 3. Unit behaviours
# ---------------------------------------------------------------------------
def test_mof_registry_tracks_transitions():
    sim = Simulation(policy="yarn", seed=4, shuffle="event")
    sim.submit(JobSpec("j0", "terasort", 2.0))
    sim.run()
    # after the run every registry entry matches the object predicate
    for t in sim.jobs["j0"].maps:
        live = sim.shuffle.registry.live.get(t.task_id, set())
        for nid in live:
            node = sim.cluster.nodes[nid]
            assert node.alive and t.task_id in node.mofs


def test_registry_drop_producer_and_node():
    sim = Simulation(policy="yarn", seed=1, shuffle="event")
    job = sim.submit(JobSpec("j0", "terasort", 1.0))

    def lose_first():
        done = [t for t in job.maps if t.state == TaskState.COMPLETED
                and t.output_nodes]
        if done:
            sim.lose_mof(done[0])
            assert sim.shuffle.registry.live.get(done[0].task_id) is None
    sim.engine.at(40.0, lose_first)
    sim.run()
    assert sim.results


def test_event_engine_does_less_selection_work():
    """The point of the refactor: slot filling stops being O(n_deps)."""
    def run(mode):
        sim = Simulation(policy="yarn", seed=0, shuffle=mode)
        sim.submit(JobSpec("j0", "terasort", 4.0))
        sim.run()
        return sim.shuffle.profile
    ev, rs, ba = run("event"), run("rescan"), run("batch")
    assert ev.slots_filled == rs.slots_filled == ba.slots_filled
    assert ev.selection_work < rs.selection_work / 10  # ...far less work
    assert ev.heap_pops and rs.deps_scanned
    # the batch plane applies one lane record per slot outcome and
    # notifies without per-subscriber scalar work
    assert ba.lane_records and ba.selection_work <= ev.selection_work
    assert ba.try_calls < ev.try_calls  # the budget gate skips no-ops


def test_shuffle_columns_written_through():
    sim = Simulation(policy="yarn", seed=2, shuffle="event")
    sim.submit(JobSpec("j0", "terasort", 2.0))
    seen = {"inflight": 0}

    def peek():
        arr = sim.arrays
        seen["inflight"] = max(seen["inflight"],
                               int(arr.sh_inflight[:arr.n].max(initial=0)))
        sim.verify_arrays()
    for t in range(20, 200, 9):
        sim.engine.at(float(t), peek)
    sim.run()
    assert seen["inflight"] > 0  # transfers were visible in the columns


def test_reduce_attempt_progress_uses_shuffle_state():
    sim = Simulation(policy="yarn", seed=3, shuffle="event")
    job = sim.submit(JobSpec("j0", "terasort", 2.0))
    probed = []

    def probe():
        for t in job.reduces:
            for a in t.running_attempts():
                if a.shuffle is not None and not a.compute_started:
                    probed.append(a.progress())
    for t in range(30, 120, 5):
        sim.engine.at(float(t), probe)
    sim.run()
    assert probed and all(0.0 <= p <= 1.0 for p in probed)


def test_shuffle_mode_selection_and_default():
    assert Simulation(policy="yarn").shuffle.mode == "batch"
    assert Simulation(policy="yarn",
                      shuffle="event").shuffle.mode == "event"
    assert Simulation(policy="yarn",
                      shuffle="rescan").shuffle.mode == "rescan"
    with pytest.raises(ValueError):
        Simulation(policy="yarn", shuffle="nope")


def test_dispatcher_owns_pending_queue():
    sim = Simulation(policy="yarn", seed=0)
    job = sim.submit(JobSpec("j0", "terasort", 1.0))
    # `pending` is a compatibility view computed from the dispatcher's
    # per-tenant queues (PR 9) — same contents, fresh list per call.
    assert sim.pending == sim.sched.pending
    sim.engine.run(until=5.0, stop=lambda: False)
    assert job.maps  # job launched, queue drained into containers
    assert all(t.kind in (TaskKind.MAP, TaskKind.REDUCE)
               for t in job.tasks)
    assert not any(a.state != AttemptState.RUNNING
                   for t in job.maps for a in t.attempts)
