"""Unit tests for the policy engine: glance, collective ramp, dependency
tracking, rollback planning, and the two speculators."""
import numpy as np
import pytest

from repro.core import (
    AttemptState, AttemptView, BinoConfig, BinocularSpeculator,
    ClusterSnapshot, CollectiveConfig, CollectiveSpeculation,
    DependencyConfig, DependencyTracker, FetchFailure, GlanceConfig,
    KillAttempt, MarkNodeFailed, NeighborhoodGlance, NodeView, ProgressLog,
    RollbackRegistry, SpeculateTask, TaskKind, TaskState, TaskView,
    YarnLateSpeculator, plan_rollback,
)

NODES = [f"n{i}" for i in range(8)]


def mknodes(now, silent=()):
    return {n: NodeView(node_id=n,
                        last_heartbeat=(now - 100.0 if n in silent else now),
                        total_containers=4, free_containers=4)
            for n in NODES}


def mktask(tid, node, progress, *, job="j0", kind=TaskKind.MAP,
           state=TaskState.RUNNING, start=0.0, now=10.0, spec=False,
           output_nodes=(), astate=AttemptState.RUNNING):
    att = AttemptView(attempt_id=tid + "_a0", task_id=tid, node_id=node,
                      state=astate, start_time=start, progress=progress,
                      is_speculative=spec)
    return TaskView(task_id=tid, job_id=job, kind=kind, state=state,
                    attempts=[att], output_nodes=tuple(output_nodes),
                    output_available=bool(output_nodes))


# ---------------------------------------------------------------------------
# Glance
# ---------------------------------------------------------------------------
def test_glance_failure_assessment_fires_after_threshold():
    g = NeighborhoodGlance(NODES, GlanceConfig(fail_threshold_init=10.0))
    snap = ClusterSnapshot(now=5.0, nodes=mknodes(5.0, silent=("n3",)),
                           tasks={})
    # silent for 100s > 10s threshold
    v = g.assess(snap)
    assert v.failed_nodes == ["n3"]
    # declared once, not repeatedly
    v2 = g.assess(ClusterSnapshot(now=6.0,
                                  nodes=mknodes(6.0, silent=("n3",)),
                                  tasks={}))
    assert v2.failed_nodes == []


def test_glance_eq4_adapts_threshold():
    g = NeighborhoodGlance(NODES, GlanceConfig(
        fail_threshold_init=10.0, failure_window=4,
        fail_threshold_margin=1.5, fail_threshold_max=300.0))
    # outage of ~61s observed: node silent, then a resuming heartbeat
    nodes = mknodes(0.0)
    nodes["n1"] = NodeView("n1", last_heartbeat=-60.0)
    g.assess(ClusterSnapshot(now=0.0, nodes=nodes, tasks={}))
    nodes2 = mknodes(1.0)  # n1 heartbeats again
    g.assess(ClusterSnapshot(now=1.0, nodes=nodes2, tasks={}))
    # outage measured from the last pre-gap heartbeat: 61 s × margin 1.5
    assert g.threshold_of("n1") == pytest.approx(1.5 * 61.0)


def test_glance_spatial_debounce():
    cfg = GlanceConfig(spatial_consecutive=3, enable_temporal=False,
                       enable_failure=False)
    g = NeighborhoodGlance(NODES, cfg)
    tasks = {}
    for i, n in enumerate(NODES):
        prog = 0.05 if n == "n2" else 0.9
        tasks[f"t{i}"] = mktask(f"t{i}", n, prog, now=10.0)
    for tick in range(2):
        v = g.assess(ClusterSnapshot(now=10.0 + tick,
                                     nodes=mknodes(10.0 + tick),
                                     tasks=tasks))
        assert v.slow_nodes == []
    v = g.assess(ClusterSnapshot(now=13.0, nodes=mknodes(13.0), tasks=tasks))
    assert ("j0", "n2", "spatial") in v.slow_nodes


def test_glance_temporal_detects_freeze():
    cfg = GlanceConfig(enable_spatial=False, enable_failure=False,
                       temporal_period=1.0)
    g = NeighborhoodGlance(NODES, cfg)

    def snap_at(now, prog):
        tasks = {"t0": mktask("t0", "n0", prog, now=now),
                 "t1": mktask("t1", "n1", prog, now=now)}
        return ClusterSnapshot(now=now, nodes=mknodes(now), tasks=tasks)

    g.assess(snap_at(0.0, 0.1))
    g.assess(snap_at(1.0, 0.2))   # builds Δ history
    g.assess(snap_at(2.0, 0.3))
    v = g.assess(snap_at(3.0, 0.3001))  # both nodes freeze
    slow = {n for _, n, _ in v.slow_nodes}
    assert slow == {"n0", "n1"}


# ---------------------------------------------------------------------------
# Collective speculation
# ---------------------------------------------------------------------------
def _straggler_snap(now, n_stragglers=4, free=4):
    tasks = {}
    for i in range(n_stragglers):
        tasks[f"t{i}"] = mktask(f"t{i}", "n0", 0.1, now=now)
    nodes = {n: NodeView(node_id=n, last_heartbeat=now, total_containers=4,
                         free_containers=free) for n in NODES}
    return ClusterSnapshot(now=now, nodes=nodes, tasks=tasks)


def test_collective_neighborhood_first_launches_all():
    c = CollectiveSpeculation(CollectiveConfig(coll_init_num=1,
                                               coll_multiply=2))
    snap = _straggler_snap(10.0)
    stragglers = [(snap.tasks[f"t{i}"], "n0", "test") for i in range(4)]
    nh = {"n0": ["n1", "n2", "n3"]}
    acts = c.plan(snap, stragglers, nh)
    # plenty of free containers in the neighborhood: everything launches
    assert len(acts) == 4
    assert all(a.placement_hint == ("n1", "n2", "n3") for a in acts)


def test_collective_ramp_geometric_when_constrained():
    c = CollectiveSpeculation(CollectiveConfig(
        coll_init_num=1, coll_multiply=2, check_period=0.0))
    snap = _straggler_snap(10.0, n_stragglers=8, free=0)  # no NH容量
    stragglers = [(snap.tasks[f"t{i}"], "n0", "x") for i in range(8)]
    nh = {"n0": ["n1"]}
    acts0 = c.plan(snap, stragglers, nh)
    assert len(acts0) == 1  # COLL_INIT_NUM
    # make the speculative copy look like it's winning
    t0 = snap.tasks["t0"]
    t0.attempts.append(AttemptView(
        attempt_id="t0_spec", task_id="t0", node_id="n1",
        state=AttemptState.RUNNING, start_time=10.0, progress=0.9,
        is_speculative=True))
    rest = [(snap.tasks[f"t{i}"], "n0", "x") for i in range(1, 8)]
    acts1 = c.plan(snap, rest, nh)
    assert len(acts1) == 2  # 1 × 2^1
    acts2 = c.plan(snap, [(snap.tasks[f"t{i}"], "n0", "x")
                          for i in range(3, 8)], nh)
    assert len(acts2) == 4  # 1 × 2^2


def test_collective_reap_only_completed_tasks():
    c = CollectiveSpeculation()
    t = mktask("t0", "n0", 1.0, state=TaskState.COMPLETED,
               astate=AttemptState.COMPLETED)
    t.attempts.append(AttemptView("t0_a1", "t0", "n1",
                                  AttemptState.RUNNING, 0.0, 0.5))
    # a re-activated producer must NOT be reaped
    t_reactivated = mktask("t1", "n0", 1.0, state=TaskState.RUNNING,
                           astate=AttemptState.COMPLETED)
    t_reactivated.attempts.append(AttemptView(
        "t1_a1", "t1", "n1", AttemptState.RUNNING, 0.0, 0.5))
    snap = ClusterSnapshot(now=1.0, nodes=mknodes(1.0),
                           tasks={"t0": t, "t1": t_reactivated})
    kills = c.reap_completed(snap)
    assert [k.attempt_id for k in kills] == ["t0_a1"]


# ---------------------------------------------------------------------------
# Dependency tracking
# ---------------------------------------------------------------------------
def test_dependency_two_consecutive_fetch_failures():
    d = DependencyTracker(DependencyConfig(fetch_failure_threshold=2))
    prod = mktask("m0", "n0", 1.0, state=TaskState.COMPLETED,
                  astate=AttemptState.COMPLETED, output_nodes=("n0",))
    snap = ClusterSnapshot(now=1.0, nodes=mknodes(1.0),
                           tasks={"m0": prod})
    f = FetchFailure(time=1.0, consumer_task_id="r0", producer_task_id="m0")
    assert d.on_fetch_failures(snap, [f]) == []
    acts = d.on_fetch_failures(snap, [f])
    assert len(acts) == 1 and acts[0].task_id == "m0"
    # a successful fetch resets the streak
    d.note_fetch_ok("m0")
    assert d.on_fetch_failures(snap, [f]) == []


def test_dependency_node_failure_respeculates_producers():
    d = DependencyTracker()
    prod = mktask("m0", "n0", 1.0, state=TaskState.COMPLETED,
                  astate=AttemptState.COMPLETED, output_nodes=("n3",))
    safe = mktask("m1", "n0", 1.0, state=TaskState.COMPLETED,
                  astate=AttemptState.COMPLETED, output_nodes=("n3", "n4"))
    snap = ClusterSnapshot(now=1.0, nodes=mknodes(1.0),
                           tasks={"m0": prod, "m1": safe})
    acts = d.on_node_failed(snap, {"n3"})
    assert [a.task_id for a in acts] == ["m0"]  # m1 has a surviving copy


# ---------------------------------------------------------------------------
# Rollback
# ---------------------------------------------------------------------------
def test_rollback_registry_keeps_most_advanced():
    r = RollbackRegistry()
    r.record(ProgressLog("t0", "n0", 0.4))
    r.record(ProgressLog("t0", "n0", 0.2))
    assert r.get("t0").offset == 0.4
    r.drop_node("n0")
    assert r.get("t0") is None


def test_plan_rollback_races_two_attempts():
    r = RollbackRegistry()
    r.record(ProgressLog("t0", "n2", 0.6))
    snap = ClusterSnapshot(now=1.0, nodes=mknodes(1.0), tasks={})
    launches = [SpeculateTask(task_id="t0", placement_hint=("n2", "n3"),
                              reason="x")]
    out = plan_rollback(snap, r, launches, unhealthy_nodes=set())
    assert len(out) == 2
    assert out[0].rollback and out[0].rollback_node == "n2"
    assert not out[1].rollback and "n2" not in out[1].placement_hint


def test_plan_rollback_skips_unhealthy_original():
    r = RollbackRegistry()
    r.record(ProgressLog("t0", "n2", 0.6))
    snap = ClusterSnapshot(now=1.0, nodes=mknodes(1.0), tasks={})
    out = plan_rollback(snap, r, [SpeculateTask(task_id="t0")],
                        unhealthy_nodes={"n2"})
    assert len(out) == 1 and not out[0].rollback


# ---------------------------------------------------------------------------
# LATE baseline myopias
# ---------------------------------------------------------------------------
def test_late_scope_limited_myopia():
    """All tasks frozen identically (one dead node) ⇒ no variation ⇒ no
    speculation — the paper's scope-limited symptom."""
    late = YarnLateSpeculator()
    tasks = {f"t{i}": mktask(f"t{i}", "n0", 0.5, start=0.0, now=100.0)
             for i in range(8)}
    snap = ClusterSnapshot(now=100.0, nodes=mknodes(100.0), tasks=tasks)
    acts = [a for a in late.assess(snap) if isinstance(a, SpeculateTask)]
    assert acts == []


def test_late_speculates_with_variation():
    late = YarnLateSpeculator()
    tasks = {f"t{i}": mktask(f"t{i}", NODES[i % 4], 0.9, now=100.0)
             for i in range(7)}
    tasks["slow"] = mktask("slow", "n5", 0.05, now=100.0)
    snap = ClusterSnapshot(now=100.0, nodes=mknodes(100.0), tasks=tasks)
    acts = [a for a in late.assess(snap) if isinstance(a, SpeculateTask)]
    assert len(acts) == 1 and acts[0].task_id == "slow"
    # serial: a second assessment within the delay launches nothing
    snap2 = ClusterSnapshot(now=101.0, nodes=mknodes(101.0), tasks=tasks)
    acts2 = [a for a in late.assess(snap2) if isinstance(a, SpeculateTask)]
    assert acts2 == []


def test_late_ignores_completed_tasks():
    """Dependency-oblivious: a completed producer with lost output is
    invisible to LATE."""
    late = YarnLateSpeculator()
    lost = mktask("m0", "n0", 1.0, state=TaskState.COMPLETED,
                  astate=AttemptState.COMPLETED)
    lost.output_available = False
    snap = ClusterSnapshot(now=100.0, nodes=mknodes(100.0),
                           tasks={"m0": lost})
    acts = [a for a in late.assess(snap) if isinstance(a, SpeculateTask)]
    assert acts == []


# ---------------------------------------------------------------------------
# Bino composition
# ---------------------------------------------------------------------------
def test_bino_failure_to_actions_pipeline():
    b = BinocularSpeculator(NODES)
    tasks = {
        "m0": mktask("m0", "n6", 1.0, state=TaskState.COMPLETED,
                     astate=AttemptState.COMPLETED, output_nodes=("n3",)),
        "r0": mktask("r0", "n1", 0.3, kind=TaskKind.REDUCE),
        "t0": mktask("t0", "n3", 0.5),
    }
    snap = ClusterSnapshot(now=50.0, nodes=mknodes(50.0, silent=("n3",)),
                           tasks=tasks)
    acts = b.assess(snap)
    kinds = [type(a).__name__ for a in acts]
    assert "MarkNodeFailed" in kinds
    spec_ids = {a.task_id for a in acts if isinstance(a, SpeculateTask)}
    assert "m0" in spec_ids  # dependency-aware completed-task re-execution
    assert "t0" in spec_ids  # running straggler on the dead node
