"""Data-pipeline resumability (the rollback substrate) and checkpoint
atomicity/retention properties."""
import os

import numpy as np
import pytest
# Property tests need hypothesis; a bare interpreter must still
# collect this module (tier-1 runs without the [test] extra) — the
# shared guard skips it wholesale when the extra is absent.
from conftest import require_hypothesis

require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data import DataState, ShardedTokenPipeline, TokenDataset


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 20), st.integers(0, 7), st.integers(0, 50),
       st.integers(1, 30))
@settings(max_examples=40, deadline=None)
def test_resume_equivalence(seed, shard, offset, ahead):
    """Batches from a resumed pipeline equal the original's — for ANY
    (seed, shard, offset): the rollback-log property."""
    ds = TokenDataset(vocab_size=128, seq_len=16, seed=seed)
    p1 = ShardedTokenPipeline.fresh(ds, shard, 8, batch_size=2)
    for _ in range(offset):
        p1.next()
    state = p1.state
    expected = [p1.next()["tokens"] for _ in range(min(ahead, 5))]
    p2 = ShardedTokenPipeline.from_state(ds, state, 2)
    got = [p2.next()["tokens"] for _ in range(min(ahead, 5))]
    for a, b in zip(expected, got):
        assert np.array_equal(a, b)


def test_shards_are_distinct_streams():
    ds = TokenDataset(vocab_size=512, seq_len=32, seed=0)
    b0 = ds.batch(0, 0, 4)
    b1 = ds.batch(1, 0, 4)
    assert not np.array_equal(b0, b1)


def test_labels_are_shifted_tokens():
    ds = TokenDataset(vocab_size=64, seq_len=8, seed=1)
    p = ShardedTokenPipeline.fresh(ds, 0, 1, batch_size=2)
    b = p.next()
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab_range():
    ds = TokenDataset(vocab_size=100, seq_len=64, seed=2)
    b = ds.batch(3, 7, 8)
    assert b.min() >= 0 and b.max() < 100


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def _tree():
    return {"w": np.arange(12.0, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones(5, np.float16),
                       "count": np.int32(3)}}


def test_roundtrip_preserves_dtype_shape(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path), t, step=5)
    restored, step, _ = restore_pytree(str(tmp_path), t)
    assert step == 5
    for a, b in zip(np.asarray(restored["w"]), t["w"]):
        np.testing.assert_array_equal(a, b)
    assert restored["nested"]["b"].dtype == np.float16


def test_latest_wins_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        t["w"] = t["w"] + 1.0
        mgr.save(t, s)
    assert mgr.latest_step() == 4
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2  # retention
    restored, step, _ = mgr.restore(t)
    assert step == 4
    np.testing.assert_array_equal(restored["w"], t["w"])


def test_shadow_race_keeps_both_until_commit(tmp_path):
    t = _tree()
    p1 = save_pytree(str(tmp_path), t, step=1, tag="primary")
    p2 = save_pytree(str(tmp_path), t, step=1, tag="shadow")
    assert p1.endswith("step_000000001")
    assert ".shadow-" in p2
    assert os.path.isdir(p1) and os.path.isdir(p2)
    # commit barrier at step 2 garbage-collects the loser
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(t, 2)
    assert not os.path.isdir(p2)
    assert os.path.isdir(p1)


def test_async_save_surfaces_and_restores(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save_async(t, 7, metadata={"datastates": [1, 2, 3]})
    mgr.wait()
    restored, step, meta = mgr.restore(t)
    assert step == 7 and meta["datastates"] == [1, 2, 3]


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_pytree(str(tmp_path), _tree())


def test_stale_tmp_swept_on_startup(tmp_path):
    """A writer that died mid-save leaves a torn ``.tmp-`` dir; the next
    CheckpointManager must sweep it and never restore from it."""
    t = _tree()
    save_pytree(str(tmp_path), t, step=3)
    torn = os.path.join(str(tmp_path), "step_000000004.tmp-primary")
    os.makedirs(torn)
    with open(os.path.join(torn, "leaf_00000.npy"), "wb") as f:
        f.write(b"\x93NUMPY partial garbage")  # no manifest, torn leaf
    mgr = CheckpointManager(str(tmp_path))
    assert not os.path.isdir(torn)
    assert mgr.latest_step() == 3
    restored, step, _ = mgr.restore(t)
    assert step == 3
    np.testing.assert_array_equal(restored["w"], t["w"])


def test_crash_before_rename_leaves_previous_intact(tmp_path):
    """Kill the writer between leaf writes and the atomic rename: the
    previously committed step must restore bit-exact (torn dirs are
    invisible to latest_step)."""
    t = _tree()
    save_pytree(str(tmp_path), t, step=1)
    # simulate the dying writer: everything written, rename never ran
    tmp = os.path.join(str(tmp_path), "step_000000002.tmp-primary")
    os.makedirs(tmp)
    np.save(os.path.join(tmp, "leaf_00000.npy"), t["w"])
    mgr = CheckpointManager(str(tmp_path))  # sweeps the orphan
    restored, step, _ = mgr.restore(t)
    assert step == 1
    np.testing.assert_array_equal(restored["w"], t["w"])
