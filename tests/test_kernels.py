"""Pallas kernel validation (interpret mode on CPU) against the pure-jnp
oracles: shape/dtype sweeps for flash attention fwd+bwd, decode attention,
and the SSD scan."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas)
from repro.kernels.decode_attention.ref import decode_attention_reference
from repro.kernels.flash_attention import flash_attention as FA
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.ssd.ref import ssd_reference
from repro.kernels.ssd.ssd import ssd_pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Flash attention forward
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,sq,sk,hq,hkv,d", [
    (1, 128, 128, 2, 2, 32),     # MHA square
    (2, 128, 128, 4, 1, 16),     # MQA
    (1, 256, 256, 4, 2, 32),     # GQA, multi-block
    (1, 128, 256, 2, 1, 32),     # decode-ish: q shorter than kv
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_fwd(b, sq, sk, hq, hkv, d, causal, dtype):
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, d), dtype)
    k = jax.random.normal(kk, (b, sk, hkv, d), dtype)
    v = jax.random.normal(kv_, (b, sk, hkv, d), dtype)
    out, _ = FA.flash_attention_fwd(q, k, v, causal=causal,
                                    block_q=64, block_k=128,
                                    interpret=True)
    ref = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_window():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 256, 2, 32), jnp.float32)
    k = jax.random.normal(key, (1, 256, 2, 32), jnp.float32)
    v = jax.random.normal(key, (1, 256, 2, 32), jnp.float32)
    out, _ = FA.flash_attention_fwd(q, k, v, causal=True, window=64,
                                    block_q=64, block_k=64, interpret=True)
    ref = attention_reference(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Flash attention backward
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv", [(2, 2), (4, 2), (4, 1)])
def test_flash_attention_bwd(hq, hkv):
    key = jax.random.PRNGKey(2)
    kq, kk, kv_, kd = jax.random.split(key, 4)
    b, s, d = 1, 128, 32
    q = jax.random.normal(kq, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(kv_, (b, s, hkv, d), jnp.float32)

    def ref_loss(q, k, v):
        o = attention_reference(q, k, v, causal=True)
        return jnp.sum(o * co)

    co = jax.random.normal(kd, (b, s, hq, d), jnp.float32)
    dq_ref, dk_ref, dv_ref = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)

    out, lse = FA.flash_attention_fwd(q, k, v, causal=True, block_q=64,
                                      block_k=64, interpret=True)
    dq, dk, dv = FA.flash_attention_bwd(q, k, v, out, lse, co, causal=True,
                                        block_q=64, block_k=64,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_ref),
                               rtol=1e-3, atol=1e-3)


def test_flash_attention_op_grad_matches_ref_impl():
    """The custom_vjp wiring end-to-end (impl='pallas' interpret)."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 128, 2, 32), jnp.float32)

    def loss(impl):
        def f(x):
            o = flash_attention(x, q, q, causal=True, impl=impl,
                                block_q=64, block_k=64)
            return jnp.sum(o ** 2)
        return f

    import repro.kernels.flash_attention.flash_attention as fa_mod
    g_ref = jax.grad(loss("ref"))(q)
    g_pal = jax.grad(loss("pallas"))(q)   # interpret on CPU by default
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,sk,hq,hkv,d", [
    (2, 128, 4, 2, 32),
    (4, 256, 4, 1, 16),
    (1, 512, 8, 8, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, sk, hq, hkv, d, dtype):
    key = jax.random.PRNGKey(4)
    kq, kk, kv_, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, hq, d), dtype)
    k = jax.random.normal(kk, (b, sk, hkv, d), dtype)
    v = jax.random.normal(kv_, (b, sk, hkv, d), dtype)
    valid = jax.random.randint(kl, (b,), 1, sk + 1, jnp.int32)
    out = decode_attention_pallas(q, k, v, valid, block_k=128,
                                  interpret=True)
    ref = decode_attention_reference(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 256, 4, 32, 32, 64),
    (1, 64, 1, 64, 16, 64),   # single chunk
])
def test_ssd_matches_reference(b, s, h, p, n, chunk):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, 1, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, 1, n), jnp.float32)
    D = jnp.ones((h,), jnp.float32)
    y_pal, st_pal = ssd_pallas(x, dt, A, B, C, D, chunk=chunk,
                               interpret=True)
    y_ref, st_ref = ssd_reference(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_pal), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_sequential_recurrence_oracle():
    """The chunked dual form equals the naive per-token recurrence."""
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 64, 2, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, 1, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, 1, n), jnp.float32)
    D = jnp.zeros((h,), jnp.float32)
    y_ref, _ = ssd_reference(x, dt, A, B, C, D, chunk=16)

    # naive recurrence
    state = np.zeros((b, h, p, n), np.float32)
    ys = []
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, B, C))
    An = np.asarray(A)
    for t in range(s):
        decay = np.exp(dtn[:, t] * An[None, :])          # (b, h)
        upd = np.einsum("bhp,bn->bhpn", xn[:, t] * dtn[:, t][..., None],
                        Bn[:, t, 0])
        state = state * decay[..., None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", state, Cn[:, t, 0]))
    y_naive = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ref), y_naive,
                               rtol=1e-4, atol=1e-4)
