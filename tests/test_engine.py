"""Discrete-event engine unit gate (ISSUE 4 satellite).

Covers the invariants every equivalence proof in this repo leans on:

1. **Stable ordering** — simultaneous events fire in scheduling order
   (the monotone seq tiebreak), including across ``run(until=)``
   pause/resume. Pins the regression where the until-pause re-push
   assigned a *fresh* seq to the deferred event, demoting it behind
   same-timestamp events that were scheduled after it.
2. **Cancellation** — cancelled handles never fire, including when
   cancelled by an earlier event at the same timestamp.
3. **BatchQueue** — the calendar lane merges with the heap in exact
   global (time, seq) order, pauses at ``until`` with records intact,
   flushes deferred state before any heap event can observe it, and
   recycles its record store only when fully drained.
"""
import pytest

from repro.sim.engine import BatchQueue, Engine


# ---------------------------------------------------------------------------
# 1. Ordering
# ---------------------------------------------------------------------------
def test_simultaneous_events_fire_in_schedule_order():
    eng = Engine()
    order = []
    for name in "abcd":
        eng.at(5.0, order.append, name)
    eng.at(1.0, order.append, "first")
    eng.run()
    assert order == ["first", "a", "b", "c", "d"]
    assert eng.now == 5.0


def test_after_orders_by_delay_then_schedule():
    eng = Engine()
    order = []
    eng.after(2.0, order.append, "late")
    eng.after(1.0, order.append, "early")
    eng.after(1.0, order.append, "early2")
    eng.run()
    assert order == ["early", "early2", "late"]


def test_until_pause_preserves_deferred_event_order():
    """The regression: pausing before time t pops the t-event and must
    re-push it *unchanged*. Re-pushing with a fresh seq reorders it
    behind same-timestamp events already in the heap."""
    eng = Engine()
    order = []
    eng.at(10.0, order.append, "A")  # scheduled first → must fire first
    eng.at(10.0, order.append, "B")
    eng.run(until=5.0)               # pops A (t > until), re-pushes it
    assert eng.now == 5.0 and order == []
    eng.run()
    assert order == ["A", "B"]


def test_until_pause_resume_across_many_pauses():
    eng = Engine()
    order = []
    for name in ("x", "y", "z"):
        eng.at(30.0, order.append, name)
    for pause in (5.0, 12.0, 29.999):
        eng.run(until=pause)
        assert order == [] and eng.now == pause
    eng.run(until=100.0)
    assert order == ["x", "y", "z"]
    assert eng.now == 100.0  # exhausted heap fast-forwards to until


def test_until_exact_boundary_fires():
    eng = Engine()
    fired = []
    eng.at(7.0, fired.append, 1)
    eng.run(until=7.0)
    assert fired == [1] and eng.now == 7.0


def test_stop_predicate_halts_before_next_event():
    eng = Engine()
    fired = []
    eng.at(1.0, fired.append, 1)
    eng.at(2.0, fired.append, 2)
    eng.run(stop=lambda: len(fired) >= 1)
    assert fired == [1]


# ---------------------------------------------------------------------------
# 2. Cancellation
# ---------------------------------------------------------------------------
def test_cancelled_events_do_not_fire():
    eng = Engine()
    fired = []
    h = eng.at(1.0, fired.append, "no")
    eng.at(1.0, fired.append, "yes")
    h.cancel()
    eng.run()
    assert fired == ["yes"]


def test_cancel_from_earlier_same_time_event():
    eng = Engine()
    fired = []
    h = [None]
    eng.at(3.0, lambda: h[0].cancel())
    h[0] = eng.at(3.0, fired.append, "victim")
    eng.run()
    assert fired == []


# ---------------------------------------------------------------------------
# 3. BatchQueue calendar lane
# ---------------------------------------------------------------------------
def _lane(eng, log):
    def apply(kind, obj, dep, payload, token):
        log.append(("rec", eng.now, kind, obj, dep, payload, token))

    def flush():
        log.append(("flush", eng.now))
    return BatchQueue(eng, apply, flush)


def test_lane_merges_with_heap_in_global_order():
    eng = Engine()
    log = []
    lane = _lane(eng, log)
    eng.at(2.0, log.append, ("heap", 2.0))
    lane.schedule(1.0, 1, "r1", 0, 0, 0)
    lane.schedule(3.0, 1, "r3", 0, 1, 0)
    eng.at(2.5, log.append, ("heap", 2.5))
    eng.run()
    events = [(e[0], e[1]) for e in log if e[0] != "flush"]
    assert events == [("rec", 1.0), ("heap", 2.0), ("heap", 2.5),
                      ("rec", 3.0)]
    # flush runs after each drain, before the next heap event
    assert log[1] == ("flush", 1.0)


def test_lane_same_time_tiebreak_follows_schedule_order():
    eng = Engine()
    log = []
    lane = _lane(eng, log)
    eng.at(5.0, log.append, ("heap", "h1"))       # seq 0
    lane.schedule(5.0, 1, "r-after-h1", 0, 0, 0)  # seq 1
    eng.at(5.0, log.append, ("heap", "h2"))       # seq 2
    lane.schedule(5.0, 1, "r-after-h2", 0, 0, 0)  # seq 3
    eng.run()
    names = [e[3] if e[0] == "rec" else e[1]
             for e in log if e[0] != "flush"]
    assert names == ["h1", "r-after-h1", "h2", "r-after-h2"]


def test_lane_until_pause_keeps_records():
    eng = Engine()
    log = []
    lane = _lane(eng, log)
    lane.schedule(10.0, 1, "late", 0, 0, 0)
    lane.schedule(1.0, 1, "early", 0, 0, 0)
    eng.run(until=5.0)
    assert eng.now == 5.0
    assert [e for e in log if e[0] == "rec"] == \
        [("rec", 1.0, 1, "early", 0, 0, 1)]
    assert len(lane) == 1  # the late record survived the pause
    eng.run()
    assert [e[3] for e in log if e[0] == "rec"] == ["early", "late"]


def test_lane_records_scheduled_during_apply_are_drained_in_order():
    eng = Engine()
    log = []

    def apply(kind, obj, dep, payload, token):
        log.append((eng.now, obj))
        if obj == "seed":
            # cascade: lands before the 4.0 heap event, after 2.0
            lane.schedule(3.0, 1, "child", 0, 0, 0)

    lane = BatchQueue(eng, apply, lambda: None)
    eng.at(4.0, log.append, "heap4")
    lane.schedule(2.0, 1, "seed", 0, 0, 0)
    eng.run()
    assert log == [(2.0, "seed"), (3.0, "child"), "heap4"]


def test_lane_store_recycles_when_fully_drained():
    eng = Engine()
    lane = BatchQueue(eng, lambda *a: None, lambda: None)
    for k in range(5):
        lane.schedule(float(k + 1), 1, f"r{k}", 0, k, 0)
    assert lane._n == 5
    eng.run()
    assert len(lane) == 0
    assert lane._n == 0 and lane.objs == []  # tokens all retired → reset
    tok = lane.schedule(99.0, 1, "fresh", 0, 0, 0)
    assert tok == 0  # slots restart after recycle


def test_lane_store_grows_past_initial_capacity():
    eng = Engine()
    hits = []
    lane = BatchQueue(eng, lambda k, o, d, p, t: hits.append((o, d)),
                      lambda: None, cap=4)
    for k in range(64):
        lane.schedule(1.0 + 0.001 * k, 1, k, 0, k, 0)
    eng.run()
    assert hits == [(k, k) for k in range(64)]


def test_lane_record_fields_round_trip():
    eng = Engine()
    seen = []
    lane = BatchQueue(eng, lambda k, o, d, p, t: seen.append((k, o, d, p)),
                      lambda: None)
    lane.schedule(2.0, 2, "obj", 7, 11, 13)
    assert int(lane._row[0]) == 7  # the introspective attempt-row field
    eng.run()
    assert seen == [(2, "obj", 11, 13)]


def test_single_lane_per_engine():
    eng = Engine()
    BatchQueue(eng, lambda *a: None, lambda: None)
    with pytest.raises(AssertionError):
        BatchQueue(eng, lambda *a: None, lambda: None)
