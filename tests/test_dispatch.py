"""Multi-tenant dispatch plane (DESIGN.md §19).

Covers the PR 9 surface: capped-launch retention and the done-job
enqueue guard (the two dispatcher bugfixes), DRR fair-share properties,
bulk ≡ scalar ≡ legacy placement equivalence, the cluster-wide
speculation budget with the ``budgeted``/``clone`` policies, and the
``pacman_workload`` / ``fleet_workload`` / ``trace_workload``
generators.
"""
import os

import numpy as np
import pytest

from conftest import assert_runs_equivalent, run_traced
from repro.core.speculator import (
    BudgetedSpeculator,
    CloneSmallJobs,
    SpeculationBudget,
)
from repro.obs.trace import K_BUDGET, TraceRecorder
from repro.sim.dispatch import LaunchRequest
from repro.sim.faults import apply_script, lose_mof_at_map_progress
from repro.sim.job import JobSpec
from repro.sim.mapreduce import Simulation
from repro.sim.runner import run_workload
from repro.sim.workload import (
    FLEET_SIZES,
    PACMAN_PROBS,
    PACMAN_SIZES,
    fleet_workload,
    pacman_workload,
    trace_workload,
)

_FUZZ_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "8"))


# ---------------------------------------------------------------------------
# Bugfix 1: capped requests are retained, metadata intact
# ---------------------------------------------------------------------------
def _run_until_maps_running(sim, job, until=20.0):
    sim.engine.run(until=until, stop=lambda: False)
    task = next(t for t in job.maps if t.running_attempts())
    return task


def test_capped_launch_request_retained_with_metadata():
    """A LaunchRequest against a task at max_running_attempts stays
    queued (the old pass silently dropped it) and launches with its
    rollback metadata once the cap clears."""
    sim = Simulation(policy="yarn", seed=0)
    job = sim.submit(JobSpec("j0", "terasort", 1.0))
    task = _run_until_maps_running(sim, job)
    sim._enqueue(LaunchRequest(task, speculative=True, reason="spec"))
    sim._dispatch()
    assert len(task.running_attempts()) == sim.params.max_running_attempts

    req = LaunchRequest(task, speculative=True, rollback=True,
                        rollback_node="n03", reason="rollback")
    sim._enqueue(req)
    sim._dispatch()
    assert req in sim.sched.pending, "capped request was dropped"
    assert sim.sched.has_queued(task)

    sim._kill_attempt(task.running_attempts()[0], "test")
    launched = []
    orig = sim._start_attempt
    sim._start_attempt = lambda r, nid: (launched.append(r), orig(r, nid))
    sim._dispatch()
    assert launched and launched[0] is req
    assert launched[0].rollback and launched[0].rollback_node == "n03"
    assert launched[0].reason == "rollback"
    assert not sim.sched.has_queued(task)


def test_capped_request_dropped_when_task_completes():
    """Retention is not a leak: a request held behind the cap is dropped
    once its task completes."""
    sim = Simulation(policy="yarn", seed=0)
    job = sim.submit(JobSpec("j0", "terasort", 1.0))
    task = _run_until_maps_running(sim, job)
    sim._enqueue(LaunchRequest(task, speculative=True))
    sim._dispatch()
    req = LaunchRequest(task, speculative=True, reason="stuck")
    sim._enqueue(req)
    sim._dispatch()
    assert sim.sched.has_queued(task)
    sim.run()
    assert not sim.sched.has_queued(task)
    assert sim.sched.pending == []


# ---------------------------------------------------------------------------
# Bugfix 2: enqueue against a done job is a no-op (MOF loss racing
# job completion must not mutate frozen state)
# ---------------------------------------------------------------------------
def test_enqueue_after_job_done_is_noop():
    sim = Simulation(policy="bino", seed=1)
    job = sim.submit(JobSpec("j0", "terasort", 1.0))
    sim.run()
    assert job.done
    task = job.maps[0]
    state_before = task.state
    done_before = job.n_maps_done
    assert done_before == len(job.maps)
    # a straggling re-execution request (completed-producer branch)
    sim.sched.enqueue(LaunchRequest(task, reason="late-mof"))
    assert sim.sched.pending == []
    assert not sim.sched.has_queued(task)
    assert task.state is state_before
    assert job.n_maps_done == done_before


def test_n_maps_done_never_negative_under_mof_loss_near_completion():
    """MOF loss injected at ~full map progress races job completion; the
    re-execution path must never push n_maps_done below zero."""
    for seed in range(4):
        sim = Simulation(policy="bino", seed=seed)
        job = sim.submit(JobSpec("j0", "terasort", 1.0))
        lose_mof_at_map_progress(sim, job, 0.99)
        sim.run()
        assert 0 <= job.n_maps_done <= len(job.maps), \
            (seed, job.n_maps_done)
        assert job.done


try:
    from hypothesis import given, settings, strategies as st

    @given(frac=st.floats(0.05, 0.999),
           seed=st.integers(0, 7),
           policy=st.sampled_from(["yarn", "bino"]))
    @settings(max_examples=_FUZZ_EXAMPLES, deadline=None)
    def test_hyp_n_maps_done_invariant(frac, seed, policy):
        sim = Simulation(policy=policy, seed=seed)
        job = sim.submit(JobSpec("j0", "terasort", 1.0))
        lose_mof_at_map_progress(sim, job, frac)
        sim.run()
        assert 0 <= job.n_maps_done <= len(job.maps)
except ImportError:  # pragma: no cover
    pass


# ---------------------------------------------------------------------------
# Queue plumbing
# ---------------------------------------------------------------------------
def test_pending_view_and_queued_index():
    sim = Simulation(policy="yarn", seed=0)
    j0 = sim.submit(JobSpec("j0", "terasort", 1.0))
    j1 = sim.submit(JobSpec("j1", "grep", 1.0))
    sim.sched.dispatch = lambda: None  # hold everything queued
    sim.engine.run(until=15.0, stop=lambda: False)
    pend = sim.sched.pending
    assert len(pend) == len(j0.maps) + len(j1.maps)
    # per-tenant FIFO, tenant rotation in arrival order
    assert [r.task.job.spec.job_id for r in pend] == \
        ["j0"] * len(j0.maps) + ["j1"] * len(j1.maps)
    for t in j0.maps:
        assert sim.sched.has_queued(t)
    del sim.sched.dispatch
    sim.run()
    assert sim.sched.pending == []
    assert sim.sched._queued == {}
    assert sim.sched._total == 0


def test_watchdog_does_not_double_enqueue():
    sim = Simulation(policy="yarn", seed=0)
    job = sim.submit(JobSpec("j0", "terasort", 1.0))
    task = _run_until_maps_running(sim, job)
    sim._kill_attempt(task.running_attempts()[0], "test")
    sim.sched.dispatch = lambda: None
    sim.sched.watchdog()
    sim.sched.watchdog()
    assert sum(1 for r in sim.sched.pending
               if r.task is task) == 1


# ---------------------------------------------------------------------------
# Fair-share (DRR) properties
# ---------------------------------------------------------------------------
def _grants_per_job(sim):
    counts = {}
    orig = sim._start_attempt

    def logged(req, node_id):
        jid = req.task.job.spec.job_id
        counts[jid] = counts.get(jid, 0) + 1
        return orig(req, node_id)

    sim._start_attempt = logged
    return counts


def _queued_multi_job(n_jobs, *, n_workers, n_containers, gb=1.0,
                      dispatch_opts=None, benches=("terasort",) * 8):
    """Simulation with every job's maps enqueued and dispatch held."""
    sim = Simulation(policy="yarn", seed=0, n_workers=n_workers,
                     n_containers=n_containers,
                     dispatch_opts=dispatch_opts)
    jobs = [sim.submit(JobSpec(f"j{i}", benches[i], gb))
            for i in range(n_jobs)]
    sim.sched.dispatch = lambda: None
    sim.engine.run(until=15.0, stop=lambda: False)
    del sim.sched.dispatch
    return sim, jobs


def test_drr_even_split_under_contention():
    """3 tenants × 8 queued maps, 6 free containers → 2 grants each: no
    tenant starves while holding demand with containers free."""
    sim, _ = _queued_multi_job(3, n_workers=2, n_containers=3)
    counts = _grants_per_job(sim)
    sim.sched.dispatch()
    assert counts == {"j0": 2, "j1": 2, "j2": 2}


def test_drr_uneven_demand_work_conserving():
    """A tenant with less demand than its share leaves the residual to
    the others (DRR is work-conserving): demand (1, 8, 8) over 6 slots
    → j0 gets its 1, the rest split 5 near-evenly."""
    sim, jobs = _queued_multi_job(3, n_workers=2, n_containers=3)
    keep = sim.sched._queues["j0"].popleft()
    while sim.sched._queues["j0"]:
        sim.sched._unindex(sim.sched._queues["j0"].popleft().task)
    sim.sched._queues["j0"].append(keep)
    counts = _grants_per_job(sim)
    sim.sched.dispatch()
    assert counts["j0"] == 1
    assert counts["j1"] + counts["j2"] == 5
    assert abs(counts["j1"] - counts["j2"]) <= 1


def test_drr_weights_bias_share():
    """weights={'j0': 2} gives j0 twice the per-cycle credit: 8 slots
    over tenants weighted (2, 1, 1) → (4, 2, 2)."""
    sim, _ = _queued_multi_job(
        4, n_workers=2, n_containers=4,
        dispatch_opts={"weights": {"j0": 2.0}})
    # drop j3 entirely: three tenants, 8 slots
    while sim.sched._queues["j3"]:
        sim.sched._unindex(sim.sched._queues["j3"].popleft().task)
    counts = _grants_per_job(sim)
    sim.sched.dispatch()
    assert counts == {"j0": 4, "j1": 2, "j2": 2}


def test_weights_validated():
    with pytest.raises(ValueError):
        Simulation(policy="yarn", seed=0,
                   dispatch_opts={"weights": {"j0": 0.0}})


def test_pass_stops_at_pool_exhaustion():
    """The placement pass stops once the free pool is provably spent:
    with 6 slots and 24 queued maps a pass grants exactly 6, the
    untried tail stays queued per-tenant FIFO (deficit credit is
    pass-local, so the early stop matches the full visit), and a pass
    against an exactly-full cluster is the O(nodes) skip."""
    sim, _ = _queued_multi_job(3, n_workers=2, n_containers=3)
    before = [r.task.task_id for r in sim.sched.pending]
    counts = _grants_per_job(sim)
    sim.sched.dispatch()
    assert sum(counts.values()) == 6
    left = [r.task.task_id for r in sim.sched.pending]
    assert len(left) == len(before) - 6
    for jid in ("j0", "j1", "j2"):
        kept = [t for t in left if t.startswith(f"{jid}_")]
        orig = [t for t in before if t.startswith(f"{jid}_")]
        assert kept == [t for t in orig if t in set(kept)]
    skipped = sim.sched.n_skipped_passes
    sim.sched.dispatch()
    assert sum(counts.values()) == 6  # no grant slipped through
    assert sim.sched.n_skipped_passes == skipped + 1


def test_completion_purges_queued_requests():
    """task_done/job_done purge eagerly: a queued launch for a task
    that completes (or a job that finishes) leaves the queues and the
    O(1) index immediately, not at the next placement pass."""
    sim = Simulation(policy="yarn", seed=0, n_workers=4, n_containers=2)
    job = sim.submit(JobSpec("j0", "terasort", 1.0))
    sim.engine.run(until=5.0, stop=lambda: False)
    t = job.maps[0]
    sim.sched.enqueue(LaunchRequest(t, speculative=True, reason="x"))
    assert sim.sched.has_queued(t)
    sim.sched.task_done(t)
    assert not sim.sched.has_queued(t)
    assert all(r.task is not t for r in sim.sched.pending)
    # job teardown drops the whole tenant queue
    for m in job.maps[1:3]:
        sim.sched.enqueue(LaunchRequest(m, speculative=True, reason="x"))
    sim.sched.job_done("j0")
    assert not any(r.task.job is job for r in sim.sched.pending)
    assert not sim.sched.has_queued(job.maps[1])


# ---------------------------------------------------------------------------
# Placement-pass equivalence: bulk ≡ scalar ≡ legacy
# ---------------------------------------------------------------------------
DISPATCH_VARIANTS = (
    ("default", None),
    ("bulk", {"bulk": True, "bulk_min": 1}),
    ("scalar", {"bulk": False}),
    ("legacy-fifo", {"fair": False, "bulk": False}),
)


def test_single_job_byte_identical_across_dispatch_variants():
    """The single-job default path is byte-identical whatever the
    dispatcher configuration — the §19 equivalence gate."""
    script = [("crash", 7, 0.45, 0.0)]
    fault = lambda sim, job: apply_script(sim, job, script)
    for policy in ("yarn", "bino"):
        runs, labels = [], []
        for label, opts in DISPATCH_VARIANTS:
            runs.append(run_traced("batch", policy, fault, seed=3,
                                   dispatch_opts=opts))
            labels.append(label)
        assert_runs_equivalent(runs, labels)


def test_multi_job_bulk_matches_scalar():
    """With several tenants the bulk pass must still make exactly the
    scalar pass's decisions (fair order fixed, placement vectorized)."""
    extra = (JobSpec("j1", "wordcount", 1.0, submit_time=4.0),
             JobSpec("j2", "grep", 2.0, submit_time=7.0),
             JobSpec("j3", "terasort", 1.0, submit_time=7.5))
    script = [("crash", 5, 0.5, 0.0)]
    fault = lambda sim, job: apply_script(sim, job, script)
    runs, labels = [], []
    for label, opts in (("bulk", {"bulk": True, "bulk_min": 1}),
                        ("scalar", {"bulk": False})):
        runs.append(run_traced("batch", "bino", fault, seed=2,
                               extra_jobs=extra, dispatch_opts=opts))
        labels.append(label)
    assert_runs_equivalent(runs, labels)
    assert runs[0].sim.sched.n_bulk_passes > 0
    assert runs[1].sim.sched.n_bulk_passes == 0


def test_profile_counters():
    run = run_traced("batch", "yarn", None, seed=1,
                     dispatch_opts={"profile": True})
    sched = run.sim.sched
    assert sched.n_grants == len(run.launches)
    assert sched.n_decisions >= sched.n_grants
    assert sched.decision_wall > 0.0


# ---------------------------------------------------------------------------
# Cluster-wide speculation budget + the budgeted/clone policies
# ---------------------------------------------------------------------------
def test_speculation_budget_meter():
    b = SpeculationBudget(2)
    assert b.capacity == 2 and b.available == 2
    assert b.admit() and b.admit() and not b.admit()
    assert (b.admitted, b.denied) == (2, 1)
    b.begin_tick(1)  # re-based on running copies, not past admissions
    assert b.available == 1
    assert b.admit() and not b.admit()
    assert SpeculationBudget(-3).capacity == 0


def test_budgeted_policy_zero_budget_never_speculates():
    specs = pacman_workload(5, seed=2, mean_interarrival=15.0)
    results = run_workload(
        "budgeted", specs, seed=4, n_workers=10, n_containers=4,
        policy_factory=lambda nodes: BudgetedSpeculator(
            budget=SpeculationBudget(0)))
    assert all(r.n_spec_attempts == 0 for r in results)


def test_clone_small_jobs_clones_upfront():
    """Small jobs get one clone per task with no straggler signal at
    all; a zero budget suppresses every clone."""
    spec = [JobSpec("j0", "terasort", 0.5)]  # 4 maps + 1 reduce ≤ 12
    cloned = run_workload("clone", spec, seed=1, n_workers=10,
                          n_containers=4)
    assert cloned[0].n_spec_attempts > 0
    starved = run_workload(
        "clone", spec, seed=1, n_workers=10, n_containers=4,
        policy_factory=lambda nodes: CloneSmallJobs(
            budget=SpeculationBudget(0)))
    assert starved[0].n_spec_attempts == 0


def test_clone_skips_large_jobs():
    """A job above the small-job threshold gets no upfront clones (LATE
    detection still applies, so pin the clone set, not spec counts)."""
    sim = Simulation(policy="clone", seed=1, n_workers=10,
                     n_containers=8)
    sim.submit(JobSpec("j0", "terasort", 4.0))  # 32 maps > 12-task cutoff
    sim.run()
    assert sim.speculator._cloned == set()


def test_budget_bounds_running_speculation():
    """At every assessment tick the number of RUNNING speculative
    copies never exceeds the budget capacity (ample containers, so
    admitted copies launch immediately)."""
    specs = [JobSpec(f"j{i}", "terasort", 0.5, submit_time=2.0 * i)
             for i in range(6)]
    sim = Simulation(policy="clone", seed=3, n_workers=20,
                     n_containers=8)
    cap = sim.speculator.budget.capacity
    assert cap > 0
    seen = []
    inner_tick = sim._speculator_tick

    def tick():
        seen.append(sim.arrays.n_running_spec())
        inner_tick()

    sim._speculator_tick = tick
    for s in specs:
        sim.submit(s)
    sim.run()
    assert seen and max(seen) <= cap
    assert sim.speculator.budget.admitted > 0


def test_budgeted_emits_budget_records():
    rec = TraceRecorder()
    script = [("slow", 2, 0.1, 0.5)]
    fault = lambda sim, job: apply_script(sim, job, script)
    run = run_traced("batch", "budgeted", fault, seed=5, obs=rec)
    ticks = rec.by_kind(K_BUDGET)
    assert len(ticks) > 0
    assert (ticks["b"] > 0).all()            # capacity recorded
    assert (ticks["f1"] <= ticks["f0"]).all()  # admitted ≤ proposed
    assert run.results[0].n_spec_attempts > 0


def test_budgeted_and_clone_obs_off_equivalence():
    """The budget policies obey the §18.2 emit-site contract: wiring
    the recorder does not perturb the trace."""
    script = [("slow", 2, 0.1, 0.5)]
    fault = lambda sim, job: apply_script(sim, job, script)
    for policy in ("budgeted", "clone"):
        a = run_traced("batch", policy, fault, seed=5)
        b = run_traced("batch", policy, fault, seed=5,
                       obs=TraceRecorder())
        assert_runs_equivalent([a, b], ["obs-off", "obs-on"])


# ---------------------------------------------------------------------------
# Workload generators (ISSUE 9 satellite: arrival-process tests)
# ---------------------------------------------------------------------------
def test_pacman_workload_deterministic_and_offsettable():
    a = pacman_workload(50, seed=3)
    assert a == pacman_workload(50, seed=3)
    assert a != pacman_workload(50, seed=4)
    shifted = pacman_workload(50, seed=3, start=100.0)
    assert all(abs((s.submit_time - t.submit_time) - 100.0) < 1e-9
               for s, t in zip(shifted, a))


def test_pacman_workload_size_mix():
    jobs = pacman_workload(4000, seed=0)
    sizes = np.array([j.input_gb for j in jobs])
    for size, p in zip(PACMAN_SIZES, PACMAN_PROBS):
        got = float(np.mean(sizes == size))
        assert abs(got - p) < 0.03, (size, got, p)
    assert all(j.submit_time > 0 for j in jobs)


def test_fleet_workload_heavy_tail_and_bursts():
    jobs = fleet_workload(2000, seed=1)
    assert jobs == fleet_workload(2000, seed=1)
    times = np.array([j.submit_time for j in jobs])
    assert (np.diff(times) >= 0).all()
    sizes = np.array([j.input_gb for j in jobs])
    assert set(np.unique(sizes)) <= set(FLEET_SIZES)
    # rank^-alpha frequencies: monotone non-increasing by rank, with
    # the smallest size clearly dominant and the tail present
    freqs = [float(np.mean(sizes == s)) for s in FLEET_SIZES]
    assert freqs[0] > 0.4
    assert freqs[-1] > 0.0
    assert all(freqs[i] >= freqs[i + 1] - 0.02
               for i in range(len(freqs) - 1))
    # MMPP over-dispersion: gap CV well above the Poisson CV of 1
    gaps = np.diff(times)
    cv = float(gaps.std() / gaps.mean())
    assert cv > 1.2, cv
    pois = np.diff([j.submit_time
                    for j in pacman_workload(2000, seed=1)])
    assert cv > float(pois.std() / pois.mean())


def test_trace_workload_sorts_and_defaults():
    jobs = trace_workload([(30.0, 2.0), (5.0, 1.0, "grep")],
                          n_reduces=3)
    assert [j.job_id for j in jobs] == ["t00000", "t00001"]
    assert jobs[0].submit_time == 5.0 and jobs[0].bench == "grep"
    assert jobs[1].bench == "terasort" and jobs[1].n_reduces == 3


def test_fleet_workload_runs_multi_tenant():
    """End-to-end: a burst of fleet jobs through every policy finishes
    with sane JCTs on all four policies."""
    specs = fleet_workload(12, seed=2, mean_interarrival=5.0,
                           burst_len=60.0, idle_len=60.0)
    for policy in ("yarn", "bino", "budgeted", "clone"):
        results = run_workload(policy, specs, seed=1, n_workers=20,
                               n_containers=4)
        assert len(results) == len(specs)
        assert all(r.jct > 0 for r in results)
