"""Distributed (sequence-parallel) flash decode vs the plain oracle —
the §Perf Cell-A optimization must be bit-for-bit semantics-preserving.

Runs on a multi-device CPU mesh: this file must execute in its own process
when the 8-device flag is needed (pytest-xdist not required — jax device
count is fixed at first init, so we skip if the host has too few devices
and provide the single-device path unconditionally).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import distributed as D
from repro.launch.mesh import make_mesh


def _case(b, h, kv, d, S, pos_vals, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    nk = jax.random.normal(ks[1], (b, kv, d), dtype)
    nv = jax.random.normal(ks[2], (b, kv, d), dtype)
    ck = jax.random.normal(ks[3], (b, S, kv, d), dtype)
    cv = jax.random.normal(ks[4], (b, S, kv, d), dtype)
    pos = jnp.asarray(pos_vals, jnp.int32)
    return q, nk, nv, ck, cv, pos


@pytest.mark.parametrize("pos_vals", [[0, 63], [5, 33], [31, 32]])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_dist_decode_matches_reference_single_shard(pos_vals, kv):
    mesh = make_mesh((1,), ("model",))
    q, nk, nv, ck, cv, pos = _case(2, 4, kv, 16, 64, pos_vals)
    out, ck2, cv2 = D.dist_decode_update_attend(q, nk, nv, ck, cv, pos,
                                                mesh=mesh)
    ref, rck, rcv = D.reference(q, nk, nv, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(ck2), np.asarray(rck))
    np.testing.assert_array_equal(np.asarray(cv2), np.asarray(rcv))


def test_dist_decode_multi_shard_if_available():
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 devices (run with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = make_mesh((len(jax.devices()) // 4, 4), ("data", "model"))
    q, nk, nv, ck, cv, pos = _case(4, 8, 2, 16, 64, [0, 15, 16, 63])
    out, ck2, _ = D.dist_decode_update_attend(q, nk, nv, ck, cv, pos,
                                              mesh=mesh)
    ref, rck, _ = D.reference(q, nk, nv, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(ck2), np.asarray(rck))


def test_model_decode_step_impl_dist_equals_ref():
    from repro.configs import get_config, reduced_config
    from repro.models import model as MODEL
    from repro.parallel.sharding import use_mesh

    mesh = make_mesh((1,), ("model",))
    cfg = reduced_config(get_config("granite-20b"))
    params = MODEL.init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              cfg.vocab_size, jnp.int32)
    _, cache = MODEL.prefill(cfg, params, {"tokens": toks}, max_len=16)
    pos = jnp.full((2,), 8, jnp.int32)
    with use_mesh(mesh):
        got, _ = MODEL.decode_step(cfg, params, cache, toks[:, -1], pos,
                                   impl="dist")
    want, _ = MODEL.decode_step(cfg, params, cache, toks[:, -1], pos,
                                impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
