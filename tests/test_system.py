"""End-to-end system behaviour: the paper's three headline mechanisms, each
demonstrated through the public API in one test."""
import numpy as np

from repro.sim import JobSpec, Simulation, faults
from repro.sim.runner import slowdown


def test_dependency_oblivious_vs_aware():
    """§II.D.1: losing a completed map's MOF stalls YARN through fetch
    failure cycles + reduce churn; Bino re-executes the producer after two
    consecutive fetch failures."""
    f = lambda sim, job: faults.lose_mof_at_map_progress(sim, job, 1.0)
    sd_y, r_y = slowdown("yarn", JobSpec("j0", "terasort", 10.0), f, seed=1)
    sd_b, r_b = slowdown("bino", JobSpec("j0", "terasort", 10.0), f, seed=1)
    assert r_y.n_fetch_failures >= 1
    assert sd_y > 1.5          # YARN visibly stalls
    assert sd_b < 0.7 * sd_y   # Bino recovers much faster


def test_scope_limited_vs_neighborhood():
    """§II.D.2: a co-located small job frozen by one node failure gives
    LATE no progress variation; the neighborhood glance + Eq. 4 monitor
    recover within seconds instead of the 600 s expiry."""
    f = lambda sim, job: faults.crash_busiest_node_at_map_progress(
        sim, job, 0.5)
    sd_y, r_y = slowdown("yarn", JobSpec("j0", "terasort", 1.0), f, seed=1)
    sd_b, r_b = slowdown("bino", JobSpec("j0", "terasort", 1.0), f, seed=1)
    assert r_y.jct > 600.0     # expiry-bound
    assert r_b.jct < 200.0     # glance-bound
    assert r_b.n_spec_attempts >= 1


def test_collective_vs_serial_speculation():
    """§III.B: under a node failure hitting many tasks at once, Bino
    launches a collective wave while LATE's serial cap trickles."""
    f = lambda sim, job: faults.crash_busiest_node_at_map_progress(
        sim, job, 0.5)
    _, r_y = slowdown("yarn", JobSpec("j0", "terasort", 1.0), f, seed=2)
    _, r_b = slowdown("bino", JobSpec("j0", "terasort", 1.0), f, seed=2)
    # LATE: at most speculative_cap × 9 tasks ≈ 1 spec; Bino: the wave
    assert r_b.n_spec_attempts > r_y.n_spec_attempts


def test_speculative_rollback_beats_scratch():
    """§III.C: recovery from a disk exception preserves spilled progress."""
    recs = {}
    for policy in ("yarn", "bino"):
        sim = Simulation(policy=policy, seed=2)
        job = sim.submit(JobSpec("j0", "wordcount", 1.0))
        faults.disk_exception_on_map(sim, job, 0, 4)  # fails after 4 spills
        sim.run()
        task = job.maps[0]
        failed = [a for a in task.attempts if a.state.value == "failed"]
        recs[policy] = task.completed_at - failed[0].end_time
    assert recs["bino"] < 0.5 * recs["yarn"]
