"""Simulator integration tests: determinism, YARN-semantics invariants,
and the paper's qualitative claims in miniature."""
import numpy as np
import pytest
# Property tests need hypothesis; a bare interpreter must still
# collect this module (tier-1 runs without the [test] extra) — the
# shared guard skips it wholesale when the extra is absent.
from conftest import require_hypothesis

require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro.sim import JobSpec, Simulation, faults
from repro.sim.engine import Engine
from repro.sim.runner import baseline_jct, run_single, slowdown


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@given(st.lists(st.tuples(st.floats(0.0, 100.0, allow_nan=False),
                          st.integers(0, 99)), min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_engine_deterministic_order(events):
    def run_once():
        eng = Engine()
        seen = []
        for t, tag in events:
            eng.at(t, lambda tag=tag: seen.append((eng.now, tag)))
        eng.run()
        return seen
    assert run_once() == run_once()
    order = [t for t, _ in run_once()]
    assert order == sorted(order)


def test_engine_cancellation():
    eng = Engine()
    fired = []
    h = eng.at(5.0, lambda: fired.append("a"))
    eng.at(6.0, lambda: fired.append("b"))
    h.cancel()
    eng.run()
    assert fired == ["b"]


# ---------------------------------------------------------------------------
# Determinism end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["yarn", "bino"])
def test_sim_bit_deterministic(policy):
    def once():
        sim = Simulation(policy=policy, seed=7)
        job = sim.submit(JobSpec("j0", "terasort", 2.0))
        faults.crash_busiest_node_at_map_progress(sim, job, 0.5)
        sim.run()
        return (job.result.jct, job.n_attempts, job.n_spec_attempts,
                job.n_fetch_failures)
    assert once() == once()


# ---------------------------------------------------------------------------
# Paper-mechanics invariants
# ---------------------------------------------------------------------------
def test_faultfree_job_completes_quickly():
    for bench in ("terasort", "wordcount", "grep"):
        r = run_single("yarn", JobSpec("j0", bench, 1.0), seed=3)
        assert r.jct < 200.0, (bench, r.jct)


def test_small_job_packs_onto_one_node():
    """The scope-limited precondition: an 8-map job fits one node."""
    sim = Simulation(policy="yarn", seed=1)
    job = sim.submit(JobSpec("j0", "terasort", 1.0))
    sim.engine.run(until=20.0, stop=lambda: False)
    nodes = {a.node_id for t in job.maps for a in t.attempts}
    assert len(nodes) == 1


def test_yarn_node_failure_bounded_by_expiry():
    """YARN recovery for a co-located small job is NM-expiry-bound."""
    sd, res = slowdown("yarn", JobSpec("j0", "terasort", 1.0),
                       lambda sim, job:
                       faults.crash_busiest_node_at_map_progress(
                           sim, job, 0.5), seed=1)
    base = baseline_jct("terasort", 1.0, seed=1)
    assert res.jct > 600.0              # waited out the expiry
    assert res.jct < 600.0 + 3 * base   # then recovered promptly


def test_bino_beats_yarn_on_node_failure():
    f = lambda sim, job: faults.crash_busiest_node_at_map_progress(
        sim, job, 0.5)
    sd_y, _ = slowdown("yarn", JobSpec("j0", "terasort", 1.0), f, seed=1)
    sd_b, _ = slowdown("bino", JobSpec("j0", "terasort", 1.0), f, seed=1)
    assert sd_y / sd_b > 3.0  # paper: ~7x; any large factor validates


def test_bino_beats_yarn_on_mof_loss():
    f = lambda sim, job: faults.lose_mof_at_map_progress(sim, job, 1.0)
    _, r_y = slowdown("yarn", JobSpec("j0", "terasort", 10.0), f, seed=1)
    _, r_b = slowdown("bino", JobSpec("j0", "terasort", 10.0), f, seed=1)
    assert r_y.n_fetch_failures >= 1     # the qualifying condition held
    assert r_y.jct > 1.5 * r_b.jct


def test_rollback_preserves_progress_monotonically():
    """Bino recovery time decreases with the spill count (Fig. 9 shape)."""
    times = []
    for k in (1, 4):
        sim = Simulation(policy="bino", seed=2)
        job = sim.submit(JobSpec("j0", "wordcount", 1.0))
        faults.disk_exception_on_map(sim, job, 0, k)
        sim.run()
        task = job.maps[0]
        failed = [a for a in task.attempts if a.state.value == "failed"]
        times.append(task.completed_at - failed[0].end_time)
    assert times[1] < 0.5 * times[0]


def test_exactly_one_output_survives_per_task():
    """Every map task of a finished job has ≥1 completed attempt, and both
    outputs of re-executed producers were retained until completion."""
    sim = Simulation(policy="bino", seed=4)
    job = sim.submit(JobSpec("j0", "terasort", 5.0))
    faults.crash_busiest_node_at_map_progress(sim, job, 0.8)
    sim.run()
    assert job.done
    for t in job.maps + job.reduces:
        completed = [a for a in t.attempts if a.state.value == "completed"]
        assert len(completed) >= 1, t.task_id


def test_transient_outage_not_declared_failed_after_learning():
    """Eq. 4: after observing a node's outage pattern, a similar transient
    does not trigger a failure verdict."""
    sim = Simulation(policy="bino", seed=5)
    sim.submit(JobSpec("j0", "aggregation", 10.0, submit_time=0.0))
    sim.submit(JobSpec("j1", "aggregation", 10.0, submit_time=100.0))
    # teaching outages: 12 s each (above the 10 s initial threshold — the
    # first will false-positive, then the threshold adapts to ~18 s)
    for i, t in enumerate((20.0, 50.0, 80.0)):
        faults.heartbeat_outage_at(sim, "n05", t, 12.0)
    faults.heartbeat_outage_at(sim, "n05", 120.0, 12.0)  # test event
    sim.run()
    late_calls = [c for c in sim.policy_failed_calls
                  if c[1] == "n05" and c[0] >= 115.0]
    assert late_calls == []


def test_stress_workload_all_jobs_finish():
    from repro.sim.runner import run_workload
    from repro.sim.workload import pacman_workload
    specs = pacman_workload(8, mean_interarrival=20.0, seed=3)
    for policy in ("yarn", "bino"):
        results = run_workload(policy, specs, seed=3)
        assert len(results) == len(specs)
