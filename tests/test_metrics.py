"""Property tests for the Eq. 1–4 math: numpy/jax implementation parity
and analytic invariants."""
import numpy as np
import pytest
# Property tests need hypothesis; a bare interpreter must still
# collect this module (tier-1 runs without the [test] extra) — the
# shared guard skips it wholesale when the extra is absent.
from conftest import require_hypothesis

require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro.core import metrics as M


# ---------------------------------------------------------------------------
# Eq. 4
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(0.1, 1000.0), min_size=1, max_size=12),
       st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_eq4_np_jax_parity(history, L):
    est_np = M.eq4_estimate_np(history, L)
    import jax.numpy as jnp
    h = history[-L:]
    padded = [np.nan] * (L - len(h)) + h
    est_jax = float(M.eq4_estimate_jax(jnp.asarray(padded, jnp.float32), L))
    # jax default dtype is f32: parity up to single precision
    assert est_np == pytest.approx(est_jax, rel=1e-5)


@given(st.lists(st.floats(0.1, 1000.0), min_size=1, max_size=8),
       st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_eq4_is_weighted_mean(history, L):
    """The estimate lies within [min, max] of the window (proper mean)."""
    est = M.eq4_estimate_np(history, L)
    window = history[-L:]
    assert min(window) - 1e-9 <= est <= max(window) + 1e-9


@given(st.floats(0.5, 500.0), st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_eq4_constant_history_is_identity(value, L):
    est = M.eq4_estimate_np([value] * L, L)
    assert est == pytest.approx(value, rel=1e-9)


def test_eq4_recency_weighting():
    """The most recent outage dominates: 2^{L+1-k} halves per step back."""
    est_recent_big = M.eq4_estimate_np([1.0, 1.0, 1.0, 100.0], 4)
    est_recent_small = M.eq4_estimate_np([100.0, 1.0, 1.0, 1.0], 4)
    assert est_recent_big > 50.0
    assert est_recent_small < 10.0


def test_eq4_empty():
    assert M.eq4_estimate_np([], 4) is None


# ---------------------------------------------------------------------------
# Eq. 1
# ---------------------------------------------------------------------------
@given(st.integers(3, 12), st.integers(2, 6), st.data())
@settings(max_examples=100, deadline=None)
def test_spatial_np_jax_parity(n_nodes, k, data):
    import jax.numpy as jnp
    P = np.array(data.draw(st.lists(
        st.one_of(st.floats(0.0, 10.0, allow_subnormal=False),
                  st.just(np.nan)),
        min_size=n_nodes, max_size=n_nodes)))
    k = min(k, n_nodes)
    offsets = np.arange(k) - (k // 2)
    nh = (np.arange(n_nodes)[:, None] + offsets[None, :]) % n_nodes
    m_np = M.spatial_slow_mask_np(P, nh)
    m_jax = np.asarray(M.spatial_slow_mask_jax(jnp.asarray(P),
                                               jnp.asarray(nh)))
    # the np path runs in f64, jax in f32: ignore knife-edge disagreements
    # where P sits within float epsilon of the mean−σ decision boundary
    Pn = P[nh]
    valid = ~np.isnan(Pn)
    cnt = np.maximum(valid.sum(axis=1), 1)
    mean = np.nansum(Pn, axis=1) / cnt
    var = np.nansum(np.where(valid, (Pn - mean[:, None]) ** 2, 0.0),
                    axis=1) / cnt
    margin = np.abs(P - (mean - np.sqrt(var)))
    decisive = ~np.isnan(margin) & (margin > 1e-4 * (1.0 + np.abs(P)))
    assert np.array_equal(m_np[decisive], m_jax[decisive])


def test_spatial_uniform_never_fires():
    """Identical progress rates: no node is slow (σ=0, strict <)."""
    P = np.full(8, 3.0)
    nh = (np.arange(8)[:, None] + np.arange(4)[None, :] - 2) % 8
    assert not M.spatial_slow_mask_np(P, nh).any()


def test_spatial_dead_node_fires():
    P = np.array([1.0, 1.0, 1.0, 0.01, 1.0, 1.0, 1.0, 1.0])
    nh = (np.arange(8)[:, None] + np.arange(4)[None, :] - 2) % 8
    mask = M.spatial_slow_mask_np(P, nh)
    assert mask[3]
    assert mask.sum() == 1


def test_spatial_single_live_node_cannot_fire():
    """Scope-limited myopia precondition: one node alone has no
    neighborhood variation to compare against."""
    P = np.full(8, np.nan)
    P[2] = 0.001  # very slow, but alone
    nh = (np.arange(8)[:, None] + np.arange(4)[None, :] - 2) % 8
    assert not M.spatial_slow_mask_np(P, nh).any()


# ---------------------------------------------------------------------------
# Eq. 2–3
# ---------------------------------------------------------------------------
@given(st.integers(2, 10), st.data())
@settings(max_examples=100, deadline=None)
def test_temporal_np_jax_parity(n, data):
    import jax.numpy as jnp
    f = st.floats(0.0, 100.0, allow_subnormal=False, width=32)
    zn = np.array(data.draw(st.lists(f, min_size=n, max_size=n)))
    zp = np.array(data.draw(st.lists(f, min_size=n, max_size=n)))
    dp = np.array(data.draw(st.lists(
        st.one_of(f, st.just(np.nan)), min_size=n, max_size=n)))
    m_np, d_np = M.temporal_slow_mask_np(zn, zp, 3.0, dp)
    m_j, d_j = M.temporal_slow_mask_jax(
        jnp.asarray(zn), jnp.asarray(zp), 3.0, jnp.asarray(dp))
    # ignore knife-edge rows (f32 vs f64 rounding of the strict ratio test)
    margin = np.abs(d_np - 0.1 * dp)
    decisive = np.isnan(margin) | (margin > 1e-4 * (1.0 + np.abs(d_np)))
    assert np.array_equal(m_np[decisive], np.asarray(m_j)[decisive])
    np.testing.assert_allclose(d_np, np.asarray(d_j),
                               rtol=1e-5, atol=1e-5)


def test_temporal_cliff_fires():
    zeta_prev = np.array([10.0, 10.0])
    zeta_now = np.array([10.05, 13.0])  # node 0 nearly frozen
    delta_prev = np.array([1.0, 1.0])
    mask, _ = M.temporal_slow_mask_np(zeta_now, zeta_prev, 3.0, delta_prev)
    assert mask[0] and not mask[1]


def test_temporal_needs_prior_delta():
    mask, _ = M.temporal_slow_mask_np(
        np.array([0.0]), np.array([0.0]), 3.0, np.array([np.nan]))
    assert not mask.any()
