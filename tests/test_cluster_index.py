"""Property gate for the free-container index (ISSUE 3 satellite).

``Cluster.pick_container`` now serves the pack-first scan from a lazy
min-heap of node positions instead of an O(n_workers) walk. The pick
must stay *identical* to the seed's linear scan under any interleaving
of occupy / release / crash / restore and any preference/exclusion set
— these tests drive random schedules and compare against the reference
scan after every step.
"""
import numpy as np
import pytest

from repro.sim.cluster import Cluster

from conftest import HAVE_HYPOTHESIS

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st


def _linear_pick(cluster, preference, exclude=None):
    """The seed's O(n_workers) scan, verbatim."""
    exclude = exclude or set()
    for nid in preference:
        n = cluster.nodes.get(nid)
        if n is not None and n.alive and nid not in exclude \
                and n.free_containers > 0:
            return nid
    for nid in cluster.node_ids:
        n = cluster.nodes[nid]
        if n.alive and nid not in exclude and n.free_containers > 0:
            return nid
    return None


def _apply_op(cluster, op, rng, counter):
    """One mutation, with the substrate's note_free discipline: every
    event that can open a slot re-arms the index (mapreduce.py calls
    cluster.note_free from _arr_node_free / completion / restore)."""
    nid = cluster.node_ids[int(rng.integers(0, len(cluster.node_ids)))]
    node = cluster.nodes[nid]
    if op == 0:      # launch: consume via the picker itself
        got = cluster.pick_container([nid])
        if got is not None:
            cluster.nodes[got].busy.add(f"a{next(counter)}")
    elif op == 1:    # attempt finished / killed: release a container
        if node.busy:
            node.busy.discard(next(iter(node.busy)))
        cluster.note_free(nid)
    elif op == 2:    # crash
        node.fail()
        cluster.note_free(nid)
    else:            # restore
        node.restore()
        cluster.note_free(nid)


def _random_query(cluster, rng):
    ids = cluster.node_ids
    pref = [ids[i] for i in rng.integers(0, len(ids),
                                         size=rng.integers(0, 3))]
    excl = {ids[i] for i in rng.integers(0, len(ids),
                                         size=rng.integers(0, 4))}
    return pref, excl


def _check_schedule(ops, n_workers, n_containers, seed):
    import itertools
    rng = np.random.default_rng(seed)
    cluster = Cluster(n_workers, n_containers)
    counter = itertools.count()
    for op in ops:
        _apply_op(cluster, op, rng, counter)
        pref, excl = _random_query(cluster, rng)
        got = cluster.pick_container(pref, exclude=set(excl))
        want = _linear_pick(cluster, pref, excl)
        assert got == want, (got, want, pref, sorted(excl))
    # Index invariant: every alive node with a free slot is armed.
    for i, nid in enumerate(cluster.node_ids):
        n = cluster.nodes[nid]
        if n.alive and n.free_containers > 0:
            assert cluster._in_heap[i], nid


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_pick_matches_linear_scan_hypothesis():
    @settings(max_examples=60, deadline=None)
    @given(ops=st.lists(st.integers(min_value=0, max_value=3),
                        min_size=1, max_size=150),
           n_workers=st.integers(min_value=1, max_value=9),
           n_containers=st.integers(min_value=1, max_value=3),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def inner(ops, n_workers, n_containers, seed):
        _check_schedule(ops, n_workers, n_containers, seed)
    inner()


def test_pick_matches_linear_scan_seeded():
    # Bare-interpreter fallback: long seeded random schedules.
    rng = np.random.default_rng(11)
    for trial in range(8):
        ops = list(rng.integers(0, 4, size=300))
        _check_schedule(ops, int(rng.integers(1, 10)),
                        int(rng.integers(1, 4)), int(rng.integers(1e9)))


def test_exhausted_cluster_returns_none():
    c = Cluster(2, 1)
    assert c.pick_container([]) == "n00"
    c.nodes["n00"].busy.add("a")
    c.nodes["n01"].busy.add("b")
    assert c.pick_container([]) is None
    assert c.pick_container([], exclude={"n00"}) is None
    c.nodes["n01"].busy.clear()
    c.note_free("n01")
    assert c.pick_container([]) == "n01"


def test_excluded_nodes_stay_armed():
    c = Cluster(3, 1)
    # n00 excluded by the query must remain pickable afterwards.
    assert c.pick_container([], exclude={"n00"}) == "n01"
    assert c.pick_container([]) == "n00"
