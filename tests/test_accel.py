"""Equivalence gate for the assessment-compute backends (DESIGN.md §13.3).

Four layers:

1. **Backend trace parity** — seeded simulations under crash / delay /
   MOF-loss / fetch-quorum faults must emit byte-identical action traces
   and job results whether the vectorized policies compute on the
   ``numpy`` reference backend, the jit ``jax`` backend, or the
   ``pallas`` backend in interpret mode.
2. **DeviceColumns invariants** (hypothesis) — after arbitrary
   grow/sync/deactivate/compact sequences, the padded device mirror
   equals the live columns on ``[:n]`` and holds exact pad fills beyond,
   with power-of-two monotone capacities.
3. **Batched sweep parity** — one vmapped device step across a fault
   scenario grid equals the same clones scored serially on the numpy
   backend, bit for bit.
4. Unit behaviours: the percentile mirror vs ``np.percentile``, backend
   registry resolution, LATE eligibility gating.
"""
import dataclasses

import numpy as np
import pytest

from repro.accel import BACKENDS, get_backend
from repro.accel.base import AssessmentBackend
from repro.core.arrays import ArraySnapshot, DeviceColumns
from repro.core.types import AttemptState, TaskKind, TaskState
from repro.sim import JobSpec, Simulation, faults
from repro.sim.mapreduce import SimParams

from conftest import HAVE_HYPOTHESIS

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st


# ---------------------------------------------------------------------------
# Harness (mirrors tests/test_columnar.py)
# ---------------------------------------------------------------------------
def _crash(sim, job):
    faults.crash_busiest_node_at_map_progress(sim, job, 0.4)


def _delay(sim, job):
    def fire():
        counts = {}
        for t in job.maps:
            for a in t.running_attempts():
                counts[a.node_id] = counts.get(a.node_id, 0) + 1
        victim = max(sorted(counts), key=lambda n: counts[n]) \
            if counts else sim.cluster.node_ids[0]
        sim.set_node_speed(victim, 0.05)
        sim.engine.after(150.0, sim.set_node_speed, victim, 1.0)
    sim.engine.at(30.0, fire)


def _mof(sim, job):
    faults.lose_mof_at_map_progress(sim, job, 1.0)


def _quorum(sim, job):
    # Wide MOF loss: many reducers report, the AM's too-many-fetch-
    # failures quorum trips and re-runs the producer.
    faults.lose_mof_at_map_progress(sim, job, 1.0, max_stragglers=16)


def _run(policy, backend, fault, seed=1, gb=2.0):
    sim = Simulation(policy=policy, seed=seed, assess_backend=backend,
                     record_actions=True)
    job = sim.submit(JobSpec("j0", "terasort", gb))
    fault(sim, job)
    results = sim.run()
    return sim, results


def _result_key(results):
    return [(r.job_id, r.finish_time, r.n_attempts, r.n_spec_attempts,
             r.n_fetch_failures) for r in results]


_REF_CACHE = {}


def _reference(policy, fault, seed=1):
    key = (policy, fault.__name__, seed)
    if key not in _REF_CACHE:
        _REF_CACHE[key] = _run(policy, None, fault, seed)
    return _REF_CACHE[key]


# ---------------------------------------------------------------------------
# 1. Backend trace parity on the fault grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("policy,fault", [
    ("yarn", _crash), ("yarn", _quorum),
    ("bino", _delay), ("bino", _mof),
])
def test_backend_traces_identical(policy, fault, backend):
    ref, rres = _reference(policy, fault)
    dev, dres = _run(policy, backend, fault)
    assert ref.action_trace == dev.action_trace
    assert _result_key(rres) == _result_key(dres)
    assert dev.action_trace, "scenario produced no actions — not probing"


def test_backend_traces_identical_bino_crash_jax():
    # Crash drives Eq. 4 (failure masks) + straggler extraction + the
    # collective ramp's winning test through the device path.
    ref, rres = _reference("bino", _crash)
    dev, dres = _run("bino", "jax", _crash)
    assert ref.action_trace == dev.action_trace
    assert _result_key(rres) == _result_key(dres)


# ---------------------------------------------------------------------------
# 2. DeviceColumns padding/compaction invariants
# ---------------------------------------------------------------------------
def _check_mirror(arr: ArraySnapshot, dc: DeviceColumns):
    host = dc.refresh(arr.active_jobs())
    n = arr.n
    assert dc.cap >= max(n, 1)
    assert dc.cap & (dc.cap - 1) == 0, "capacity must stay a power of two"
    for name, fill in DeviceColumns._FILLS.items():
        buf = host[name]
        assert len(buf) == dc.cap
        assert np.array_equal(buf[:n], getattr(arr, name)[:n])
        pad = buf[n:]
        expect = np.full(dc.cap - n, fill, dtype=pad.dtype)
        assert np.array_equal(pad, expect), name
    assert np.array_equal(host["order"][:n], arr.order())
    assert not host["order"][n:].any()
    assert host["n_rows"] == n


def _snapshot_ops(arr: ArraySnapshot, ops, rng):
    """Replay an op script against a raw snapshot (no simulator)."""
    jidx = arr.job_started("j0")
    owners = []
    for op in ops:
        if op == 0 or not owners:   # add a row
            o = type("O", (), {"row": -1})()
            t_order = len(owners) // 2
            if t_order * 2 == len(owners):   # first attempt of a task
                arr.task_created(jidx)
            o.row = arr.add_attempt(
                o, f"a{len(owners)}", f"t{t_order}", t_order,
                len(owners) % 2, jidx, int(rng.integers(0, 4)),
                TaskKind.MAP if t_order % 2 else TaskKind.REDUCE,
                bool(rng.integers(0, 2)), float(rng.random()),
                0.0, 1.0 + float(rng.random()), 3, TaskState.RUNNING)
            owners.append(o)
        elif op == 1:               # progress sync
            o = owners[int(rng.integers(0, len(owners)))]
            arr.sync_row(o.row, float(rng.random()), float(rng.random()))
        elif op == 2:               # end an attempt
            o = owners[int(rng.integers(0, len(owners)))]
            arr.set_attempt_state(o.row, AttemptState.COMPLETED)
        elif op == 3:               # deactivate everything (job done)...
            arr.job_finished("j0")
            arr.job_started("j0")   # ...and reopen for later adds
        else:                       # force physical compaction
            arr._compact()
    return arr


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_device_columns_mirror_hypothesis():
    @settings(max_examples=40, deadline=None)
    @given(ops=st.lists(st.integers(min_value=0, max_value=4),
                        min_size=1, max_size=120),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def inner(ops, seed):
        rng = np.random.default_rng(seed)
        arr = ArraySnapshot([f"n{i:02d}" for i in range(4)])
        dc = DeviceColumns(arr)
        caps = []
        for cut in range(0, len(ops), 17):
            _snapshot_ops(arr, ops[cut:cut + 17], rng)
            _check_mirror(arr, dc)
            caps.append(dc.cap)
        assert caps == sorted(caps), "capacity must never shrink"
    inner()


def test_device_columns_mirror_seeded():
    # Bare-interpreter fallback for the same invariants.
    rng = np.random.default_rng(7)
    arr = ArraySnapshot([f"n{i:02d}" for i in range(4)])
    dc = DeviceColumns(arr)
    ops = list(rng.integers(0, 5, size=400))
    for cut in range(0, len(ops), 23):
        _snapshot_ops(arr, ops[cut:cut + 23], rng)
        _check_mirror(arr, dc)


def test_device_columns_repad_after_compaction():
    # Rows vacated by compaction must return to exact pad fills.
    arr = ArraySnapshot(["n00", "n01"])
    rng = np.random.default_rng(0)
    _snapshot_ops(arr, [0] * 60, rng)       # 60 live rows
    dc = DeviceColumns(arr)
    _check_mirror(arr, dc)
    arr.job_finished("j0")                  # all rows dead
    arr._compact()
    arr.job_started("j0")
    _check_mirror(arr, dc)
    assert arr.n == 0


# ---------------------------------------------------------------------------
# 3. Batched sweep parity (device vmap vs serial numpy)
# ---------------------------------------------------------------------------
def _mid_run_snapshot(n_workers=20, n_jobs=3, cap_s=80.0, seed=5):
    params = dataclasses.replace(SimParams(), sim_time_cap=cap_s)
    sim = Simulation(policy="yarn", seed=seed, n_workers=n_workers,
                     params=params)
    for j in range(n_jobs):
        sim.submit(JobSpec(f"j{j}", "terasort", 2.0,
                           submit_time=float(3 * j)))
    sim.run()
    return sim


def test_batched_sweep_matches_serial_numpy():
    from repro.accel.sweep import BatchedSweep, scenario_grid
    sim = _mid_run_snapshot()
    assert sim.arrays.n > 0 and sim.active_jobs
    scenarios = scenario_grid(8, n_nodes=20, seed=1)
    assert {s.kind for s in scenarios} == {
        "crash", "delay", "mof_loss", "fetch_quorum"}
    sweep = BatchedSweep(sim.arrays, sim.engine.now).prepare(scenarios)
    serial = sweep.run_serial()
    batched = sweep.run_batched()
    assert len(serial) == len(batched) == 8
    for a, b in zip(serial, batched):
        assert set(a) == set(b)
        for k in a:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k
    # the grid must actually diversify assessment outcomes
    sigs = {repr(s) for s in serial}
    assert len(sigs) > 1, "scenario grid produced identical verdicts"


def test_scenario_grid_deterministic():
    from repro.accel.sweep import scenario_grid
    assert scenario_grid(12, 50, seed=3) == scenario_grid(12, 50, seed=3)
    assert scenario_grid(12, 50, seed=3) != scenario_grid(12, 50, seed=4)


# ---------------------------------------------------------------------------
# 4. Unit behaviours
# ---------------------------------------------------------------------------
def test_percentile_mirror_matches_numpy():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.accel.jax_backend import np_percentile_sorted
    rng = np.random.default_rng(0)
    with enable_x64():
        for m in list(range(1, 24)) + [101]:
            vals = rng.random(m) * rng.choice([1e-6, 1.0, 1e6])
            srt = np.sort(vals)
            padded = np.concatenate([srt, np.full(7, np.inf)])
            for q in (25.0, 50.0, 75.0, 90.0):
                got = float(np_percentile_sorted(
                    jnp.asarray(padded), jnp.int64(m), jnp.float64(q),
                    jnp.float64(1.0)))
                want = float(np.percentile(vals, q))
                assert got == want, (m, q, got, want)


def test_backend_registry():
    for name in BACKENDS:
        b = get_backend(name)
        assert isinstance(b, AssessmentBackend)
        assert b.name == name
        assert get_backend(b) is b
    assert get_backend(None).name == "numpy"
    with pytest.raises(ValueError):
        get_backend("cuda")


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_shared_backend_instance_across_snapshots(backend):
    # get_backend passes instances through, so one backend may serve two
    # interleaved simulations whose tick clocks coincide — per-tick memos
    # must key on the snapshot, not just on `now`.
    sim1 = _mid_run_snapshot(seed=5)
    sim2 = _mid_run_snapshot(seed=9)
    t = max(sim1.engine.now, sim2.engine.now) + 1.0
    shared = get_backend(backend)
    out1 = shared.late_victims(
        sim1.arrays, t, sim1.arrays.active_jobs(),
        np.ones(len(sim1.arrays.active_jobs()), dtype=bool), 10.0, 25.0)
    out2 = shared.late_victims(
        sim2.arrays, t, sim2.arrays.active_jobs(),
        np.ones(len(sim2.arrays.active_jobs()), dtype=bool), 10.0, 25.0)
    fresh = get_backend(backend)
    want2 = fresh.late_victims(
        sim2.arrays, t, sim2.arrays.active_jobs(),
        np.ones(len(sim2.arrays.active_jobs()), dtype=bool), 10.0, 25.0)
    assert np.array_equal(out2, want2)
    r1 = shared.reap_rows(sim1.arrays, t)
    r2 = shared.reap_rows(sim2.arrays, t)
    assert np.array_equal(r2, fresh.reap_rows(sim2.arrays, t))
    assert np.array_equal(r1, fresh.reap_rows(sim1.arrays, t))
    del out1


def test_late_victims_respects_eligibility():
    sim = _mid_run_snapshot(n_jobs=2)
    arr = sim.arrays
    now = sim.engine.now
    active = arr.active_jobs()
    assert active
    b = get_backend("numpy")
    none_eligible = np.zeros(len(active), dtype=bool)
    victims = b.late_victims(arr, now, active, none_eligible, 10.0, 25.0)
    assert (victims == -1).all()
