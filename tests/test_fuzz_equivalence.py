"""Differential fault-script fuzzer (ISSUE 4 satellite; DESIGN.md §14.4).

Four shuffle engines (rescan / event / batch / kernel) and two
assessment backends (numpy / jax) now coexist, each promising
byte-identical behaviour on the flat and topo networks. This suite
composes random fault scripts from the ``sim/faults.py`` primitives —
crash (± restore), slowdown, heartbeat outage, silent MOF loss, disk
exception — at random times / progress fractions, runs the same seeded
script under every configuration, and asserts byte-identical speculator
action traces, attempt-launch sequences (time, task, node, reason,
speculative, rollback) and job results.

On the ε-fair network the kernel engine is NOT trace-comparable to
batch: folding milestones and ticks into the calendar lane moves drain
boundaries, and the fair model re-solves its share tables per drain, so
rates are priced at shifted instants (the DESIGN.md §17.3 cadence
waiver). The fair column is therefore pinned differentially *within*
the kernel engine — staged bulk tables vs scalar accounting vs the
generic record-at-a-time drain, and numpy vs jax bulk solvers — plus
invariant sweeps; drain-boundary reallocation (§17.4) shifts traces by
design and is pinned on invariants only.

Two layers:

1. **Pinned corpus** — fixed-seed scripts spanning every primitive and
   the nasty compositions (crash during shuffle, MOF loss + slowdown,
   disk exception + crash). Runs on a bare interpreter — this is the
   deterministic CI gate (`make test-fuzz` widens the hypothesis budget
   on top).
2. **Hypothesis strategies** — random scripts over the same primitives
   (REPRO_FUZZ_EXAMPLES scales the budget), plus a fused-vs-generic
   drain parity fuzz for the batch lane and mid-run invariant sweeps.

The jax column of the matrix is itself equivalence-gated per scenario
in tests/test_accel.py; here it rides the same scripts so a fetch-plane
change can never diverge only under a device backend.
"""
import os

import pytest

from conftest import (
    HAVE_HYPOTHESIS,
    HAVE_JAX,
    assert_runs_equivalent,
    check_invariants,
    run_traced,
)
from repro.sim import JobSpec, faults

SHUFFLES = ("rescan", "event", "batch", "kernel")
BACKENDS = ("numpy",) + (("jax",) if HAVE_JAX else ())

_FUZZ_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "8"))


# ---------------------------------------------------------------------------
# Fault-script interpretation: every step is a plain tuple, so scripts
# are printable, picklable, and identical across the matrix runs — and
# since ISSUE 6 the interpreter lives in sim/faults.py, shared with the
# live-runtime chaos layer (one script, two worlds; DESIGN.md §16.4).
# ---------------------------------------------------------------------------
apply_script = faults.apply_script


def script_fault(script):
    def fault(sim, job):
        apply_script(sim, job, script)
    return fault


def run_matrix(script, *, policy, seed, gb=1.0, shuffles=SHUFFLES,
               backends=BACKENDS, checks=None, net="flat", racks=0):
    runs, labels = [], []
    for backend in backends:
        for mode in shuffles:
            runs.append(run_traced(
                mode, policy, script_fault(script), seed=seed, gb=gb,
                assess_backend=backend, net=net, racks=racks,
                checks=checks if mode in ("batch", "kernel") else None))
            labels.append(f"{mode}/{backend}")
    assert_runs_equivalent(runs, labels)
    assert runs[0].launches, "scenario launched nothing — not probing"
    return runs


# ---------------------------------------------------------------------------
# 1. Pinned corpus (bare-interpreter deterministic gate)
# ---------------------------------------------------------------------------
# (name, policy, seed, script) — every step (kind, node_idx, x, y).
PINNED = [
    ("crash_mid_map", "yarn", 1,
     [("crash", 3, 0.15, 0.0)]),
    ("crash_during_shuffle", "bino", 3,
     [("crash", 7, 0.45, 0.0)]),
    ("crash_restore_rejoin", "bino", 2,
     [("crash_restore", 5, 0.2, 0.6)]),
    ("slow_straggler", "yarn", 1,
     [("slow", 11, 0.1, 0.3)]),
    ("hb_outage_confusion", "bino", 4,
     [("hb", 9, 0.25, 0.8)]),
    ("mof_loss_stall", "yarn", 2,
     [("mof", 0, 0.9, 0.9)]),
    ("disk_exception_rollback", "bino", 5,
     [("disk", 2, 0.0, 0.5)]),
    ("mof_plus_slowdown", "bino", 2,
     [("mof", 0, 0.85, 1.0), ("slow", 4, 0.3, 0.2)]),
    ("crash_after_disk_exception", "yarn", 3,
     [("disk", 1, 0.0, 0.9), ("crash", 6, 0.5, 0.0)]),
    ("triple_fault", "bino", 1,
     [("crash_restore", 2, 0.12, 0.4), ("mof", 0, 0.8, 0.6),
      ("hb", 14, 0.5, 0.5)]),
]


@pytest.mark.parametrize("name,policy,seed,script",
                         PINNED, ids=[p[0] for p in PINNED])
def test_pinned_scripts_equivalent_across_matrix(name, policy, seed,
                                                 script):
    run_matrix(script, policy=policy, seed=seed,
               checks=range(20, 700, 45))


# Network-fault corpus (ISSUE 5 satellite): rack-switch degradation,
# link cuts and whole-rack partitions — alone and composed with the
# classic primitives — pinned across rescan/event/batch on both the
# flat and the 4-rack topo network (the rack primitives are topology
# no-ops or whole-cluster events on flat; equivalence must hold there
# too). The job is 6 GB so pack-first placement spills across racks
# (48 maps on n00–n05 = racks 0–1) — a 1 GB job co-locates inside one
# rack and never crosses an uplink. The rack-degrade scenario runs
# under BOTH speculation policies (acceptance gate).
NET_GB = 6.0
PINNED_NET = [
    ("rack_degrade_yarn", "yarn", 2, [("degrade", 0, 0.2, 0.3)]),
    ("rack_degrade_bino", "bino", 3,
     [("degrade", 0, 0.25, 0.1), ("slow", 2, 0.3, 0.4)]),
    ("link_cut_recovery", "bino", 1, [("cut", 1, 0.25, 0.5)]),
    ("rack_partition_heal", "yarn", 4, [("part", 1, 0.3, 0.7)]),
    ("cut_plus_mof", "bino", 2,
     [("cut", 3, 0.3, 0.4), ("mof", 0, 0.85, 0.8)]),
    ("cut_then_crash", "yarn", 3,
     [("cut", 4, 0.2, 0.9), ("crash", 4, 0.5, 0.0)]),
]


@pytest.mark.parametrize("net,racks", [("flat", 0), ("topo", 4)],
                         ids=["flat", "topo4"])
@pytest.mark.parametrize("name,policy,seed,script",
                         PINNED_NET, ids=[p[0] for p in PINNED_NET])
def test_pinned_net_scripts_equivalent_across_matrix(name, policy, seed,
                                                     script, net, racks):
    run_matrix(script, policy=policy, seed=seed, gb=NET_GB, net=net,
               racks=racks, backends=("numpy",),
               checks=range(20, 700, 45))


def test_pinned_net_scripts_probe_faults():
    """The network corpus must actually bend behavior on the 4-rack
    topology: a degraded uplink / cut link / partition shows up as a
    JCT shift against the fault-free run, fetch failures, or recovery
    launches."""
    probed = 0
    for name, policy, seed, script in PINNED_NET:
        base = run_traced("batch", policy, None, seed=seed, gb=NET_GB,
                          net="topo", racks=4)
        r = run_traced("batch", policy, script_fault(script), seed=seed,
                       gb=NET_GB, net="topo", racks=4)
        jct_shift = abs(r.results[0].finish_time
                        - base.results[0].finish_time) > 1.0
        extra = sum(1 for launch in r.launches if launch[3])
        fetch_fail = sum(res.n_fetch_failures for res in r.results)
        if jct_shift or extra or fetch_fail:
            probed += 1
    assert probed >= (2 * len(PINNED_NET)) // 3, probed


def test_pinned_scripts_probe_faults():
    """The corpus must actually exercise recovery machinery somewhere:
    re-runs, speculative copies, or fetch failures."""
    probed = 0
    for name, policy, seed, script in PINNED:
        r = run_traced("batch", policy, script_fault(script), seed=seed,
                       gb=1.0)
        extra = sum(1 for launch in r.launches if launch[3])  # reasoned
        fetch_fail = sum(res.n_fetch_failures for res in r.results)
        spec = sum(res.n_spec_attempts for res in r.results)
        if extra or fetch_fail or spec:
            probed += 1
    assert probed >= len(PINNED) // 2, probed


def test_batch_generic_drain_parity_on_pinned():
    """The fused drain loop vs the reference record-at-a-time loop:
    transition-identical on every pinned script (guards the deliberate
    inlining in BatchShuffle._drain_run and the kernel engine's lane
    foldings on top of it)."""
    for mode in ("batch", "kernel"):
        for name, policy, seed, script in PINNED:
            fused = run_traced(mode, policy, script_fault(script),
                               seed=seed, gb=1.0)
            generic = run_traced(mode, policy, script_fault(script),
                                 seed=seed, gb=1.0, generic_drain=True)
            assert_runs_equivalent(
                [fused, generic],
                [f"{mode}/{name}/fused", f"{mode}/{name}/generic"])


# ---------------------------------------------------------------------------
# Kernel engine on the ε-fair network (ISSUE 7): differential pins
# *within* the engine — see the module docstring for why batch-vs-kernel
# trace comparison is waived here (§17.3).
# ---------------------------------------------------------------------------
FAIR_RACKS = 4
# Subset of the corpus that stresses the fair model's drain cadence:
# slow/hb/crash faults bend flow lifetimes and recompute schedules.
PINNED_FAIR = [PINNED[1], PINNED[2], PINNED[3], PINNED[4], PINNED[9]]


def _fair_run(policy, seed, script, **kw):
    kw.setdefault("checks", range(20, 700, 45))
    return run_traced("kernel", policy, script_fault(script), seed=seed,
                      gb=NET_GB, net="fair", racks=FAIR_RACKS, **kw)


@pytest.mark.parametrize("name,policy,seed,script",
                         PINNED_FAIR, ids=[p[0] for p in PINNED_FAIR])
def test_pinned_fair_kernel_bulk_differential(name, policy, seed,
                                              script):
    """Staged bulk flow tables vs scalar per-flow accounting vs the
    generic record-at-a-time drain: one engine, three executions, one
    trace. Pins the frozen-rate staging in BatchShuffle._drain_run and
    FairNetwork's deferred open/close against the non-bulk reference."""
    runs = [
        _fair_run(policy, seed, script),
        _fair_run(policy, seed, script, net_opts={"bulk": False}),
        _fair_run(policy, seed, script, generic_drain=True),
    ]
    assert_runs_equivalent(runs, ["bulk/fused", "scalar/fused",
                                  "bulk/generic"])
    assert runs[0].launches, "scenario launched nothing — not probing"


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
@pytest.mark.parametrize("name,policy,seed,script",
                         PINNED_FAIR[:3], ids=[p[0]
                                               for p in PINNED_FAIR[:3]])
def test_pinned_fair_kernel_jax_bulk_solver(name, policy, seed, script):
    """The jax bulk water-fill/pricing solver must be bit-identical to
    the numpy reference through a whole faulted run (the anti-FMA
    guard in repro/accel/bulk.py is what keeps this true)."""
    runs = [
        _fair_run(policy, seed, script),
        _fair_run(policy, seed, script,
                  net_opts={"bulk_backend": "jax"}),
    ]
    assert_runs_equivalent(runs, ["bulk/numpy", "bulk/jax"])


def test_pinned_fair_realloc_invariants():
    """Drain-boundary reallocation (§17.4) shifts traces by design —
    the waiver trades byte-equivalence for invariants: every pinned
    fair scenario must complete with the full invariant sweep green,
    fused and generic drains must still agree with *each other*, and
    the corpus must actually reallocate somewhere."""
    reallocs = 0
    for name, policy, seed, script in PINNED_FAIR:
        fused = _fair_run(policy, seed, script,
                          net_opts={"realloc": True})
        generic = _fair_run(policy, seed, script,
                            net_opts={"realloc": True},
                            generic_drain=True)
        assert_runs_equivalent(
            [fused, generic],
            [f"{name}/realloc/fused", f"{name}/realloc/generic"])
        check_invariants(fused.sim)
        assert fused.results, name
        reallocs += fused.sim.shuffle.n_reallocs
    assert reallocs > 0, "corpus never reallocated — not probing §17.4"


def test_multi_job_matrix_equivalence():
    extra = (JobSpec("j1", "wordcount", 0.5, submit_time=25.0),
             JobSpec("j2", "grep", 0.5, submit_time=40.0))
    runs, labels = [], []
    for mode in SHUFFLES:
        runs.append(run_traced(
            mode, "bino", script_fault([("crash", 6, 0.3, 0.0)]),
            seed=4, gb=1.0, extra_jobs=extra))
        labels.append(mode)
    assert_runs_equivalent(runs, labels)
    assert len(runs[0].results) == 3


# ---------------------------------------------------------------------------
# Dispatch column (ISSUE 9): the multi-tenant plane's placement passes.
# On a single job every dispatcher configuration — DRR default, forced
# bulk, forced scalar, and the legacy global FIFO — must be
# byte-identical (the §19 single-job equivalence gate). With several
# tenants the *fair* order is fixed and bulk vs scalar placement must
# still agree decision-for-decision; the legacy FIFO is excluded there
# (different service order by design).
# ---------------------------------------------------------------------------
DISPATCH_VARIANTS = (
    ("default", None),
    ("bulk", {"bulk": True, "bulk_min": 1}),
    ("scalar", {"bulk": False}),
    ("legacy-fifo", {"fair": False, "bulk": False}),
)


@pytest.mark.parametrize("name,policy,seed,script",
                         PINNED, ids=[p[0] for p in PINNED])
def test_pinned_scripts_equivalent_across_dispatch(name, policy, seed,
                                                   script):
    for mode in ("batch", "kernel"):
        runs, labels = [], []
        for label, opts in DISPATCH_VARIANTS:
            runs.append(run_traced(mode, policy, script_fault(script),
                                   seed=seed, gb=1.0,
                                   dispatch_opts=opts))
            labels.append(f"{mode}/{label}")
        assert_runs_equivalent(runs, labels)


def test_multi_job_bulk_scalar_dispatch_equivalence():
    extra = (JobSpec("j1", "wordcount", 0.5, submit_time=6.0),
             JobSpec("j2", "grep", 1.0, submit_time=8.0),
             JobSpec("j3", "terasort", 0.5, submit_time=9.0))
    for mode in ("batch", "kernel"):
        runs, labels = [], []
        for label, opts in (("bulk", {"bulk": True, "bulk_min": 1}),
                            ("scalar", {"bulk": False})):
            runs.append(run_traced(
                mode, "bino", script_fault([("crash", 6, 0.3, 0.0)]),
                seed=4, gb=1.0, extra_jobs=extra, dispatch_opts=opts))
            labels.append(f"{mode}/{label}")
        assert_runs_equivalent(runs, labels)
        assert len(runs[0].results) == 4


# ---------------------------------------------------------------------------
# 2. Hypothesis: random fault scripts
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    from hypothesis import example, given, settings, strategies as st

    _step = st.tuples(
        st.sampled_from(["crash", "crash_restore", "slow", "hb", "mof",
                         "disk"]),
        st.integers(0, 19),           # victim node / map index
        st.floats(0.0, 1.0),          # time / progress fraction
        st.floats(0.0, 1.0))          # magnitude / duration scale

    _script = st.lists(_step, min_size=1, max_size=3)

    @given(script=_script, seed=st.integers(0, 7),
           policy=st.sampled_from(["yarn", "bino"]))
    @settings(max_examples=_FUZZ_EXAMPLES, deadline=None)
    @example(script=[("mof", 0, 0.9, 1.0), ("crash", 3, 0.4, 0.0)],
             seed=2, policy="bino")
    @example(script=[("disk", 0, 0.0, 1.0), ("crash_restore", 1, 0.3, 0.5)],
             seed=1, policy="yarn")
    def test_random_scripts_equivalent_across_shuffles(script, seed,
                                                       policy):
        """The cheap, wide net: every shuffle engine on the numpy
        backend (the jax column rides the pinned corpus — per-example
        device sweeps would blow the fuzz budget)."""
        run_matrix(script, policy=policy, seed=seed, backends=("numpy",))

    _net_step = st.tuples(
        st.sampled_from(["degrade", "cut", "part", "crash", "slow",
                         "mof"]),
        st.integers(0, 9),            # victim node / rack / map index
        st.floats(0.0, 1.0),          # time / progress fraction
        st.floats(0.0, 1.0))          # magnitude / duration scale

    _net_script = st.lists(_net_step, min_size=1, max_size=3)

    @given(script=_net_script, seed=st.integers(0, 7),
           policy=st.sampled_from(["yarn", "bino"]))
    @settings(max_examples=_FUZZ_EXAMPLES, deadline=None)
    @example(script=[("degrade", 0, 0.2, 0.1), ("cut", 3, 0.4, 0.5)],
             seed=3, policy="bino")
    @example(script=[("part", 1, 0.3, 0.6), ("mof", 0, 0.9, 1.0)],
             seed=1, policy="yarn")
    def test_random_net_scripts_equivalent_across_shuffles(script, seed,
                                                           policy):
        """Rack/link fault scripts on the 4-rack topo network: every
        shuffle engine must agree transfer-for-transfer while uplinks
        degrade, links cut and racks partition mid-shuffle."""
        run_matrix(script, policy=policy, seed=seed, gb=NET_GB,
                   net="topo", racks=4, backends=("numpy",))

    @given(script=_script, seed=st.integers(0, 7))
    @settings(max_examples=max(_FUZZ_EXAMPLES // 2, 4), deadline=None)
    @example(script=[("mof", 0, 0.9, 1.0), ("crash", 3, 0.4, 0.0)],
             seed=2)
    def test_random_scripts_equivalent_across_dispatch(script, seed):
        """Random fault scripts through every dispatcher configuration
        on a single job: the §19 gate under fuzz."""
        runs = [run_traced("batch", "bino", script_fault(script),
                           seed=seed, gb=1.0, dispatch_opts=opts)
                for _label, opts in DISPATCH_VARIANTS]
        assert_runs_equivalent(runs,
                               [label for label, _ in DISPATCH_VARIANTS])

    @given(script=_script, seed=st.integers(0, 7))
    @settings(max_examples=max(_FUZZ_EXAMPLES // 2, 4), deadline=None)
    def test_random_scripts_fused_vs_generic_drain(script, seed):
        fused = run_traced("batch", "bino", script_fault(script),
                           seed=seed, gb=1.0)
        generic = run_traced("batch", "bino", script_fault(script),
                            seed=seed, gb=1.0, generic_drain=True)
        assert_runs_equivalent([fused, generic], ["fused", "generic"])

    @given(script=_script, seed=st.integers(0, 5))
    @settings(max_examples=max(_FUZZ_EXAMPLES // 2, 4), deadline=None)
    def test_random_scripts_hold_batch_invariants(script, seed):
        """Status partition, MOF registry, completion-log cursors,
        idle-set mirror and lane-token consistency under random fault
        schedules, swept mid-run and at the end state."""
        r = run_traced("batch", "bino", script_fault(script), seed=seed,
                       gb=1.0, checks=range(5, 900, 13))
        check_invariants(r.sim)

    @given(script=_net_script, seed=st.integers(0, 5),
           policy=st.sampled_from(["yarn", "bino"]))
    @settings(max_examples=max(_FUZZ_EXAMPLES // 2, 4), deadline=None)
    @example(script=[("slow", 4, 0.3, 0.2), ("hb", 9, 0.25, 0.8)],
             seed=2, policy="bino")
    def test_random_fair_kernel_bulk_differential(script, seed, policy):
        """Random rack/link/classic fault scripts on the ε-fair network:
        the kernel engine's staged bulk tables, scalar accounting and
        generic drain must stay trace-identical, with the invariant
        sweep green on the bulk run."""
        runs = [
            _fair_run(policy, seed, script,
                      checks=range(20, 700, 45)),
            _fair_run(policy, seed, script, net_opts={"bulk": False}),
            _fair_run(policy, seed, script, generic_drain=True),
        ]
        assert_runs_equivalent(runs, ["bulk/fused", "scalar/fused",
                                      "bulk/generic"])
        check_invariants(runs[0].sim)
