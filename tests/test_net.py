"""Network substrate gates (ISSUE 5; DESIGN.md §15).

Four layers:

1. **Seed byte-identity** — the flat model extracted from the seed
   ``Cluster.fetch_throughput`` must reproduce pre-refactor ``main``
   action traces bit-for-bit. The fingerprints below were recorded on
   the commit before ``repro/net`` existed (same container, same seeds);
   every engine must still hash to them.
2. **Topo equivalence** — 1-rack topo degenerates to flat byte-for-byte
   (also pinning the generic ``open_flow`` path against BatchShuffle's
   inlined flat arithmetic); multi-rack topo agrees across engines.
3. **ε-fair allocator properties** — capacity, work conservation,
   monotonicity under flow removal (exact max-min, ε=0), and exact
   agreement with the flat shares on degenerate 1-rack patterns
   (fan-out / fan-in / disjoint pairs; general two-sided patterns
   diverge — the hub counterexample below is the documented §15.3
   fidelity trade).
4. **Fault units** — link cut/restore registry semantics, rack-degrade
   end-to-end slowdown, and the seed-compat local-flow double-count fix
   behind its flag (§15.4).
"""
import hashlib

import pytest

from conftest import (
    HAVE_HYPOTHESIS,
    HAVE_JAX,
    assert_runs_equivalent,
    check_invariants,
    run_traced,
)
from repro.net import DISK_BW, NIC_BW, FairNetwork, FlatNetwork, TopoNetwork
from repro.sim import Cluster, JobSpec, Simulation, faults

SHUFFLES = ("rescan", "event", "batch")


def fp(run) -> str:
    return hashlib.sha256(repr(run.key()).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# 1. Seed byte-identity (recorded on pre-refactor main)
# ---------------------------------------------------------------------------
def _crash_mof(sim, job):
    faults.crash_node_at(sim, sim.cluster.node_ids[7], 55.0)
    faults.lose_mof_at_map_progress(sim, job, 0.9, max_stragglers=3)


def _slow_hb(sim, job):
    faults.slow_node_at(sim, sim.cluster.node_ids[4], 40.0, factor=0.05,
                        duration=120.0)
    faults.heartbeat_outage_at(sim, sim.cluster.node_ids[9], 60.0,
                               duration=45.0)


SEED_FINGERPRINTS = [
    # (scenario, policy, seed, engines, fingerprint)
    (_crash_mof, "yarn", 3, SHUFFLES, "059c90959f3012d2"),
    (_crash_mof, "bino", 3, SHUFFLES, "9bf223a003c8c67c"),
    (_slow_hb, "yarn", 5, ("batch",), "96e5403cf18af4e2"),
    (_slow_hb, "bino", 5, ("batch",), "ce1941cb85569b27"),
    (None, "yarn", 1, ("batch",), "a0e88f161c2bcaad"),
    (None, "bino", 1, ("batch",), "9ccb6a30f96b8737"),
]


@pytest.mark.parametrize(
    "fault,policy,seed,engines,want", SEED_FINGERPRINTS,
    ids=[f"{p}-s{s}-{(f.__name__ if f else 'nofault')}"
         for f, p, s, _e, _w in SEED_FINGERPRINTS])
def test_flat_matches_pre_refactor_main(fault, policy, seed, engines,
                                        want):
    for mode in engines:
        r = run_traced(mode, policy, fault, seed=seed, gb=1.0)
        assert fp(r) == want, (mode, fp(r))


# ---------------------------------------------------------------------------
# 2. Topo equivalence
# ---------------------------------------------------------------------------
def test_topo_one_rack_is_flat_byte_identical():
    for policy in ("yarn", "bino"):
        flat = run_traced("batch", policy, _crash_mof, seed=3, gb=1.0)
        topo = run_traced("batch", policy, _crash_mof, seed=3, gb=1.0,
                          net="topo", racks=1)
        assert_runs_equivalent([flat, topo], ["flat", "topo-1rack"])


def test_topo_multi_rack_equivalent_across_engines():
    runs = [run_traced(m, "bino", _crash_mof, seed=3, gb=6.0, net="topo",
                       racks=4, checks=range(20, 700, 45))
            for m in SHUFFLES]
    assert_runs_equivalent(runs, list(SHUFFLES))


def test_topo_oversubscribed_uplink_caps_cross_rack_rate():
    net = TopoNetwork(racks=4, oversub=4.0)
    Cluster(20, 8, network=net)
    # 5 nodes/rack → uplink = 5·NIC/4; a lone cross-rack flow is
    # NIC-limited, but a degraded uplink binds first.
    assert net.rate_probe("n00", "n05") == NIC_BW
    net.set_uplink_factor(0, 0.1)
    up = 5 * NIC_BW / 4.0 * 0.1
    assert net.rate_probe("n00", "n05") == up
    assert net.rate_probe("n00", "n01") == NIC_BW  # intra-rack unaffected
    r = net.open_flow("n00", "n05")
    assert r == up and net.rack_flows.tolist() == [1, 1, 0, 0]
    net.close_flow("n00", "n05")
    assert net.rack_flows.tolist() == [0, 0, 0, 0]


# ---------------------------------------------------------------------------
# 3. ε-fair allocator properties
# ---------------------------------------------------------------------------
def _fair(n_workers=12, racks=1, eps=0.0, **kw) -> FairNetwork:
    net = FairNetwork(racks=racks, eps=eps, **kw)
    Cluster(n_workers, 8, network=net)
    return net


def _open_all(net, flows):
    for s, d in flows:
        net.open_flow(f"n{s:02d}", f"n{d:02d}")
    net._recompute()
    return net.flow_rates(), net.active_flow_links()


def _check_capacity_and_conservation(net, eps):
    import numpy as np
    rates = net.flow_rates()
    links = net.active_flow_links()
    eff = net._eff_cap()
    use = np.zeros(len(eff))
    for r, row in zip(rates, links):
        for l in row:
            if l < 0:
                break
            use[l] += r
    assert (use <= eff * (1.0 + eps) + 1e-6).all(), \
        (use - eff).max()
    # work conservation: every flow is pinned by some saturated link
    for r, row in zip(rates, links):
        row = [l for l in row if l >= 0]
        assert any(use[l] >= eff[l] / (1.0 + eps) - 1e-6 for l in row), \
            (r, row, [float(use[l]) for l in row])


FAIR_PINNED = [
    # (n_workers, racks, flows) — deterministic bare-interpreter cases
    (12, 1, [(0, 1), (0, 2), (0, 3)]),                 # fan-out
    (12, 1, [(1, 0), (2, 0), (3, 0)]),                 # fan-in
    (12, 1, [(0, 1), (2, 3), (4, 5)]),                 # disjoint pairs
    (12, 1, [(0, 0), (1, 1), (0, 2)]),                 # locals + remote
    # hub counterexample: leaf→leaf flow outruns the flat min-share
    (12, 1, [(0, 1), (0, 2), (0, 3), (1, 2)]),
    (12, 3, [(0, 4), (0, 5), (4, 8), (1, 1), (5, 6)]),  # cross-rack mix
]


@pytest.mark.parametrize("n,racks,flows", FAIR_PINNED,
                         ids=[f"case{i}" for i in range(len(FAIR_PINNED))])
def test_fair_pinned_capacity_and_conservation(n, racks, flows):
    net = _fair(n, racks=racks, eps=0.0)
    _open_all(net, flows)
    _check_capacity_and_conservation(net, 0.0)


def test_fair_matches_flat_on_degenerate_one_rack_patterns():
    """Fan-out, fan-in and disjoint pairs: the max-min share equals the
    flat instantaneous share min(C/n_src, C/n_dst) exactly (same
    float division). General two-sided patterns legitimately diverge —
    the hub case below gives the leaf→leaf flow the capacity freed by
    the saturated hub, which the flat rule cannot see (§15.3)."""
    for k in (1, 2, 5):
        net = _fair(12)
        rates, _ = _open_all(net, [(0, d + 1) for d in range(k)])
        assert all(r == NIC_BW / k for r in rates), (k, rates)
        net = _fair(12)
        rates, _ = _open_all(net, [(s + 1, 0) for s in range(k)])
        assert all(r == NIC_BW / k for r in rates), (k, rates)
    net = _fair(12)
    rates, _ = _open_all(net, [(0, 1), (2, 3), (4, 4)])
    assert rates[0] == NIC_BW and rates[1] == NIC_BW
    assert rates[2] == DISK_BW
    # the documented divergence: hub saturates at NIC/3, the leaf→leaf
    # flow takes the leaf's remaining 2/3 NIC (flat would cap it at 1/2)
    net = _fair(12)
    rates, _ = _open_all(net, [(0, 1), (0, 2), (0, 3), (1, 2)])
    assert rates[0] == rates[1] == rates[2] == pytest.approx(NIC_BW / 3)
    assert rates[3] == pytest.approx(2 * NIC_BW / 3)
    assert rates[3] > min(NIC_BW / 2, NIC_BW / 2)  # beats the flat rule


def test_fair_monotone_under_flow_removal_pinned():
    """Max-min monotonicity is a *bottleneck* property: removing a flow
    never hurts the worst-off survivor (the max-min objective can only
    grow when the feasible region grows). Per-flow rates are NOT
    monotone — in the hub case, removing one hub flow lets the others
    expand into the leaf link and squeezes the leaf→leaf flow from
    2/3·C to 1/2·C — so the gate is on the minimum."""
    for n, racks, flows in FAIR_PINNED:
        if len(flows) < 2:
            continue
        net = _fair(n, racks=racks, eps=0.0)
        rates, _ = _open_all(net, flows)
        net2 = _fair(n, racks=racks, eps=0.0)
        rates2, _ = _open_all(net2, flows[1:])
        assert rates2.min() >= rates.min() - 1e-9, (flows, rates, rates2)


def test_fair_drain_freeze_and_lazy_recompute():
    net = _fair(8)
    net.open_flow("n00", "n01")
    k0 = net.n_recomputes
    assert k0 == 1                     # no lane yet: solved inline
    net.begin_drain()
    assert net.n_recomputes == k0      # clean at drain start: reuse
    net.open_flow("n00", "n02")
    net.open_flow("n03", "n04")
    assert net.n_recomputes == k0      # frozen: no per-launch solve
    net.end_drain()
    net.begin_drain()
    assert net.n_recomputes == k0 + 1  # dirty → re-solved at next drain
    net.end_drain()
    net.open_flow("n05", "n06")
    assert net.n_recomputes == k0 + 1  # lane seen: opens stay O(1)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    _flow = st.tuples(st.integers(0, 11), st.integers(0, 11))

    @given(flows=st.lists(_flow, min_size=1, max_size=24),
           racks=st.sampled_from([1, 2, 3]),
           eps=st.sampled_from([0.0, 0.05]))
    @settings(max_examples=60, deadline=None)
    def test_fair_capacity_and_conservation_random(flows, racks, eps):
        net = _fair(12, racks=racks, eps=eps)
        _open_all(net, flows)
        _check_capacity_and_conservation(net, eps)

    @given(flows=st.lists(_flow, min_size=2, max_size=16),
           drop=st.integers(0, 15), racks=st.sampled_from([1, 2]))
    @settings(max_examples=40, deadline=None)
    def test_fair_monotone_under_flow_removal_random(flows, drop, racks):
        # bottleneck monotonicity (see the pinned test's docstring for
        # why per-flow rates are legitimately non-monotone)
        drop = drop % len(flows)
        net = _fair(12, racks=racks, eps=0.0)
        rates, _ = _open_all(net, flows)
        keep = [f for i, f in enumerate(flows) if i != drop]
        net2 = _fair(12, racks=racks, eps=0.0)
        rates2, _ = _open_all(net2, keep)
        assert rates2.min() >= rates.min() - 1e-9, (flows, drop)

    @given(k=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_fair_matches_flat_fanout_random(k):
        net = _fair(12)
        rates, _ = _open_all(net, [(0, d + 1) for d in range(k)])
        assert all(r == NIC_BW / k for r in rates)


# ---------------------------------------------------------------------------
# 4. Fair model in the simulator (invariant-based equivalence)
# ---------------------------------------------------------------------------
def test_fair_simulation_completes_under_both_policies():
    for policy in ("yarn", "bino"):
        r = run_traced("batch", policy, _crash_mof, seed=3, gb=2.0,
                       net="fair", racks=2, checks=range(20, 700, 45))
        assert len(r.results) == 1 and r.results[0].finish_time > 0
        assert r.sim.cluster.net.n_recomputes > 0
        check_invariants(r.sim)


def test_fair_all_engines_complete_the_job():
    """Invariant-based equivalence for the fair model: the recompute
    cadence differs per engine (per-drain vs per-event), so traces may
    legitimately shift — but every engine must finish the same job with
    the same task structure and healthy invariants."""
    jcts = {}
    for mode in SHUFFLES:
        r = run_traced(mode, "bino", _crash_mof, seed=3, gb=2.0,
                       net="fair", racks=2, checks=range(20, 700, 45))
        assert len(r.results) == 1
        jcts[mode] = r.results[0].finish_time
    lo, hi = min(jcts.values()), max(jcts.values())
    assert hi <= 2.0 * lo, jcts  # same physics, bounded cadence skew


def test_fair_fused_vs_generic_drain_parity():
    fused = run_traced("batch", "bino", _crash_mof, seed=3, gb=2.0,
                       net="fair", racks=2)
    generic = run_traced("batch", "bino", _crash_mof, seed=3, gb=2.0,
                         net="fair", racks=2, generic_drain=True)
    assert_runs_equivalent([fused, generic], ["fused", "generic"])


def test_fair_per_flow_mode_matches_drain_mode_completions():
    for mode_opt in ("drain", "flow"):
        r = run_traced("batch", "yarn", None, seed=1, gb=1.0, net="fair",
                       net_opts={"recompute": mode_opt})
        assert len(r.results) == 1


# ---------------------------------------------------------------------------
# 5. Link faults + seed-compat accounting fix
# ---------------------------------------------------------------------------
def test_link_cut_drops_and_restores_mof_sources():
    sim = Simulation(policy="yarn", seed=1, net="topo", racks=4)
    sim.submit(JobSpec("j0", "terasort", 1.0))
    sim.engine.run(until=50.0, stop=lambda: False)
    reg = sim.shuffle.registry
    # find a node holding MOFs
    victim = next(nid for nid in sim.cluster.node_ids
                  if sim.cluster.nodes[nid].mofs)
    held = set(sim.cluster.nodes[victim].mofs)
    assert any(victim in reg.live.get(t, ()) for t in held)
    sim.cut_link(victim)
    assert all(victim not in reg.live.get(t, ()) for t in held)
    assert sim.cluster.nodes[victim].heartbeat_suppressed(sim.engine.now)
    assert not sim.cluster.net.node_link_up[
        sim.cluster._node_pos[victim]]
    # a completion on the cut node must not re-enter the live set
    sim.verify_network()
    sim.restore_link(victim)
    assert all(victim in reg.live.get(t, ()) for t in held)
    assert bool(sim.cluster.net.node_link_up[
        sim.cluster._node_pos[victim]])


def test_rack_degrade_slows_cross_rack_job_end_to_end():
    """The paper's degraded-network scenario: a sick rack switch, not a
    sick node — the job crossing that uplink slows dramatically while
    every node stays healthy."""
    base = run_traced("batch", "yarn", None, seed=2, gb=6.0, net="topo",
                      racks=4)

    def deg(sim, job):
        faults.rack_switch_degrade_at(sim, 0, 45.0, 0.02)
    hit = run_traced("batch", "yarn", deg, seed=2, gb=6.0, net="topo",
                     racks=4, checks=range(20, 900, 60))
    assert hit.results[0].finish_time > 2.0 * base.results[0].finish_time
    assert not hit.sim.truth_crashed  # no node ever died


def test_batched_sweep_includes_rack_degrade_scenarios():
    """The scenario grid grows a rack_degrade column under a rack
    topology, perturbing the §15 net columns on the clone (never the
    live snapshot) — and the vmapped device step scores it identically
    to the serial numpy reference."""
    import dataclasses as dc

    import numpy as np

    from repro.accel.sweep import BatchedSweep, apply_scenario, scenario_grid
    from repro.sim.mapreduce import SimParams

    params = dc.replace(SimParams(), sim_time_cap=70.0)
    sim = Simulation(policy="yarn", seed=2, params=params, net="topo",
                     racks=4)
    sim.submit(JobSpec("j0", "terasort", 6.0))
    sim.run()
    scenarios = scenario_grid(10, len(sim.cluster.node_ids), seed=1,
                              n_racks=4)
    kinds = {sc.kind for sc in scenarios}
    assert "rack_degrade" in kinds, kinds
    sc = next(s for s in scenarios if s.kind == "rack_degrade")
    clone = sim.arrays.clone_for_assessment()
    apply_scenario(clone, sc, sim.engine.now)
    rack = sc.rack % 4
    assert clone.rack_factor[rack] == sc.factor
    assert sim.arrays.rack_factor[rack] == 1.0  # live state untouched
    hit = np.flatnonzero(clone.sh_fail[:clone.n]
                         > sim.arrays.sh_fail[:clone.n])
    assert (clone.node_rack[clone.node[hit]] == rack).all()
    if HAVE_JAX:
        sweep = BatchedSweep(sim.arrays, sim.engine.now).prepare(scenarios)
        serial = sweep.run_serial()
        batched = sweep.run_batched()
        for s, b in zip(serial, batched):
            for key in ("spatial_hits", "failed", "late_victims",
                        "winning"):
                assert (np.asarray(s[key]) == np.asarray(b[key])).all(), key
            assert s["n_reap"] == b["n_reap"]


def test_flat_symmetric_fix_counts_local_flows_once():
    compat = FlatNetwork()
    Cluster(4, 8, network=compat)
    compat.open_flow("n00", "n00")
    assert compat.nodes["n00"].active_flows == 2  # the seed double-count
    assert compat.node_flows[0] == 2
    fixed = FlatNetwork(seed_compat=False)
    Cluster(4, 8, network=fixed)
    fixed.open_flow("n00", "n00")
    assert fixed.nodes["n00"].active_flows == 1
    assert fixed.node_flows[0] == 1
    fixed.close_flow("n00", "n00")
    assert fixed.nodes["n00"].active_flows == 0
    # remote accounting is identical under both
    assert compat.open_flow("n01", "n02") == fixed.open_flow("n01", "n02")
    assert compat.nodes["n01"].active_flows == \
        fixed.nodes["n01"].active_flows == 1


def test_flat_custom_bandwidth_equivalent_across_engines():
    """A flat model with non-default capacities must NOT claim the
    batch engine's inline fast path (which bakes NIC_BW/DISK_BW in) —
    all engines take the generic route and stay trace-identical."""
    opts = {"nic_bw": NIC_BW / 2, "disk_bw": DISK_BW / 2}
    assert not FlatNetwork(**opts).inline_flat
    runs = [run_traced(m, "yarn", _crash_mof, seed=3, gb=1.0,
                       net_opts=opts, checks=range(20, 700, 45))
            for m in SHUFFLES]
    assert_runs_equivalent(runs, list(SHUFFLES))
    # and the halved bandwidth genuinely changes the schedule
    ref = run_traced("batch", "yarn", _crash_mof, seed=3, gb=1.0)
    assert ref.results[0].finish_time != runs[0].results[0].finish_time


def test_overlapping_cut_windows_union():
    sim = Simulation(policy="yarn", seed=1)
    sim.engine.run(until=5.0, stop=lambda: False)
    sim.cut_link("n02", duration=20.0)   # window [5, 25]
    sim.engine.run(until=15.0, stop=lambda: False)
    sim.cut_link("n02", duration=100.0)  # window [15, 115]
    sim.engine.run(until=25.0, stop=lambda: False)
    sim.restore_link("n02")              # first window ends
    assert "n02" in sim._link_down       # still down: union [5, 115]
    assert sim.cluster.nodes["n02"].heartbeat_suppressed(sim.engine.now)
    sim.engine.run(until=115.0, stop=lambda: False)
    sim.restore_link("n02")
    assert "n02" not in sim._link_down
    assert not sim.cluster.nodes["n02"].heartbeat_suppressed(
        sim.engine.now + 1e-9)


def test_heartbeat_outage_never_shortens_cut_suppression():
    """An outage composed with a longer link cut must not resume the
    severed link's heartbeats (suppression windows union — outages
    extend, never clobber)."""
    sim = Simulation(policy="yarn", seed=1)
    faults.heartbeat_outage_at(sim, "n03", 20.0, 30.0)  # [20, 50]
    sim.engine.run(until=10.0, stop=lambda: False)
    sim.cut_link("n03", duration=300.0)                 # [10, 310]
    sim.engine.run(until=60.0, stop=lambda: False)
    assert sim.cluster.nodes["n03"].hb_suppressed_until == 310.0
    assert sim.cluster.nodes["n03"].heartbeat_suppressed(60.0)
    # and two plain outages union too
    sim2 = Simulation(policy="yarn", seed=1)
    faults.heartbeat_outage_at(sim2, "n05", 10.0, 100.0)  # [10, 110]
    faults.heartbeat_outage_at(sim2, "n05", 20.0, 10.0)   # [20, 30]
    sim2.engine.run(until=40.0, stop=lambda: False)
    assert sim2.cluster.nodes["n05"].hb_suppressed_until == 110.0


def test_rack_degrade_intensity_is_assessment_visible():
    """scenario_grid varies the degrade factor; the perturbation the
    assessment actually reads (the shuffle-health columns) must differ
    across intensities, not just the unread rack_factor."""
    from repro.accel.sweep import Scenario, apply_scenario

    sim = Simulation(policy="yarn", seed=2, net="topo", racks=4)
    sim.submit(JobSpec("j0", "terasort", 6.0))
    sim.engine.run(until=60.0, stop=lambda: False)
    deltas = {}
    for factor in (0.02, 0.10):
        clone = sim.arrays.clone_for_assessment()
        apply_scenario(clone, Scenario("rack_degrade", rack=0,
                                       factor=factor), sim.engine.now)
        deltas[factor] = int((clone.sh_fail[:clone.n]
                              - sim.arrays.sh_fail[:clone.n]).sum())
    assert deltas[0.02] == 2 * deltas[0.10] != 0, deltas


def test_overlapping_degrade_windows_union():
    """Two degrade windows on one rack: the strongest active factor
    wins and the uplink heals only when BOTH have elapsed."""
    sim = Simulation(policy="yarn", seed=1, net="topo", racks=4)
    faults.rack_switch_degrade_at(sim, 0, 10.0, 0.5, duration=100.0)
    faults.rack_switch_degrade_at(sim, 0, 50.0, 0.02, duration=100.0)
    net = sim.cluster.net
    sim.engine.run(until=20.0, stop=lambda: False)
    assert net.rack_factor[0] == 0.5
    sim.engine.run(until=60.0, stop=lambda: False)
    assert net.rack_factor[0] == 0.02      # strongest active degrade
    sim.engine.run(until=115.0, stop=lambda: False)
    assert net.rack_factor[0] == 0.02      # window 1 ended, 2 still live
    sim.engine.run(until=155.0, stop=lambda: False)
    assert net.rack_factor[0] == 1.0       # both elapsed: healed


def test_rack_degrade_scenario_rack_modulus_matches_live_path():
    """9 nodes on 4 racks leaves rack 3 empty (ceil-division): the
    sweep perturbation must target the same rack the live fault would
    — an empty victim rack perturbs nothing on either path."""
    from repro.accel.sweep import Scenario, apply_scenario

    sim = Simulation(policy="yarn", seed=1, n_workers=9, net="topo",
                     racks=4)
    sim.submit(JobSpec("j0", "terasort", 4.0))
    sim.engine.run(until=60.0, stop=lambda: False)
    assert int(sim.arrays.node_rack.max()) == 2  # rack 3 empty
    clone = sim.arrays.clone_for_assessment()
    apply_scenario(clone, Scenario("rack_degrade", rack=3, factor=0.02),
                   sim.engine.now)
    assert clone.rack_factor[3] == 0.02          # NOT remapped to rack 0
    assert (clone.sh_fail[:clone.n]
            == sim.arrays.sh_fail[:clone.n]).all()


def test_restore_link_preserves_foreign_heartbeat_outage():
    sim = Simulation(policy="yarn", seed=1)
    sim.engine.run(until=10.0, stop=lambda: False)
    # outage owns [10, 150]; a shorter cut rides on top
    sim.cluster.nodes["n04"].hb_suppressed_until = 150.0
    sim.cut_link("n04", duration=30.0)
    assert sim.cluster.nodes["n04"].hb_suppressed_until == 150.0
    sim.engine.run(until=40.0, stop=lambda: False)
    sim.restore_link("n04")
    # the cut never owned the window: the outage keeps suppressing
    assert sim.cluster.nodes["n04"].hb_suppressed_until == 150.0
    assert "n04" not in sim._link_down


def test_flat_symmetric_fix_equivalent_across_engines():
    """The fixed accounting shifts traces vs seed-compat (documented
    §15.4) but must stay engine-invariant — and it loses the inline
    fast path, so this also exercises the generic flat route through
    the batch drain."""
    runs = [run_traced(m, "bino", _crash_mof, seed=3, gb=1.0,
                       net_opts={"seed_compat": False},
                       checks=range(20, 700, 45))
            for m in SHUFFLES]
    assert_runs_equivalent(runs, list(SHUFFLES))
