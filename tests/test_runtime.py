"""Training-runtime integration: exactly-once gradient semantics under
faults, recovery behaviour of both strategies, checkpoint/restart — and
the ISSUE 6 chaos matrix: pinned declarative fault scripts (the same
tuple vocabulary the simulator's ``faults.apply_script`` interprets)
injected into live coordinator/host threads via ``ChaosController``,
on a deterministic ``FakeClock`` so no assertion races a real sleep.

The load-bearing invariant everywhere: a faulted run's final parameters
are BIT-identical to the fault-free run's (gradients are keyed by
(shard, microbatch), first writer wins, summed in sorted order).
"""
import os
import random
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.runtime import (
    ChaosController,
    FakeClock,
    RuntimeConfig,
    StepWedged,
    TrainerRuntime,
)
from repro.runtime.chaos import PINNED_SCRIPTS, parse_script
from repro.train.loop import TrainConfig

CFG = reduced_config(get_config("qwen1.5-0.5b"))
TC = TrainConfig()
HORIZON = 6.0


def _params_vec(trainer):
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(trainer.state["params"])])


def _run(recovery, steps=3, inject=None, *, script=None, fake_clock=False,
         **kw):
    clock = FakeClock(auto_advance=True) if fake_clock else None
    chaos = (ChaosController(script, horizon=HORIZON, seed=7)
             if script is not None else None)
    rt = RuntimeConfig(n_hosts=4, microbatches_per_shard=4,
                       recovery=recovery, compute_delay=0.02, **kw)
    t = TrainerRuntime(CFG, TC, rt, seq_len=32, per_shard_batch=2, seed=0,
                       clock=clock, chaos=chaos)
    try:
        reports = t.run(steps, on_step=inject)
        return _params_vec(t), reports, t.coord
    finally:
        t.shutdown()


@pytest.fixture(scope="module")
def fault_free():
    """Golden run: real clock, no chaos, differential columnar/reference
    verification enforced on every assessment tick."""
    vec, reports, _ = _run("bino", verify_columnar=True)
    return vec, reports


def test_fault_free_full_work(fault_free):
    vec, reports = fault_free
    for r in reports:
        assert r.mb_executed >= r.mb_needed
        assert not r.recoveries
        assert np.isfinite(r.metrics["loss"])


# ---------------------------------------------------------------------------
# The chaos matrix (ISSUE 6): pinned fault scripts × both recovery
# policies. Every cell must (a) complete, (b) produce BIT-identical
# parameters to the fault-free golden run. Fault timing rides the
# auto-advancing FakeClock, so wall time stays bounded while the
# failure-detection timelines play out in virtual seconds.
# ---------------------------------------------------------------------------
CHAOS_MATRIX = [(name, policy)
                for name in ("crash", "hang", "delay_hb", "drop", "dup")
                for policy in ("bino", "restart")] + [
    ("crash_restore", "bino"),
    ("hb_outage", "bino"),
    ("reorder", "bino"),
    ("cut", "bino"),
    ("crash_plus_drop", "bino"),
]


@pytest.mark.parametrize("name,policy", CHAOS_MATRIX,
                         ids=[f"{n}-{p}" for n, p in CHAOS_MATRIX])
def test_chaos_matrix_exactly_once(fault_free, name, policy):
    vec_ff, _ = fault_free
    kw = dict(restart_timeout=1.5)
    if policy == "bino":
        kw.update(repair_timeout=0.5, verify_columnar=True)
    vec, reports, _ = _run(policy, script=PINNED_SCRIPTS[name],
                           fake_clock=True, **kw)
    assert len(reports) == 3
    for r in reports:
        assert r.mb_executed >= r.mb_needed
    assert np.array_equal(vec_ff, vec), \
        f"{name}/{policy}: faulted params diverged from fault-free"
    if name.startswith("crash"):
        # a permanent host loss must surface as an explicit recovery
        assert any(r.recoveries or r.restarts for r in reports)


def test_chaos_cut_exercises_retry_backoff(fault_free):
    """A link cut from t0 eats work-item assigns; the coordinator's
    ack-deadline + jittered-backoff redelivery (and, if exhausted,
    failover) must carry the step — bit-identically."""
    vec_ff, _ = fault_free
    vec, reports, coord = _run(
        "bino", script=[("cut", 1, 0.0, 0.4)], fake_clock=True,
        repair_timeout=0.5, verify_columnar=True)
    assert np.array_equal(vec_ff, vec)
    assert coord.resend_count >= 1, "cut never exercised the retry path"


def test_chaos_duplicate_delivery_is_idempotent(fault_free):
    """Duplicated GradMessages must not double-count: mb_executed counts
    arrivals, but the gradient sum dedups on (shard, mb)."""
    vec_ff, _ = fault_free
    vec, reports, _ = _run("bino", script=PINNED_SCRIPTS["dup"],
                           fake_clock=True, verify_columnar=True)
    assert np.array_equal(vec_ff, vec)


def test_differential_decisions_under_straggler(fault_free):
    """Sim-vs-runtime differential gate: the columnar engine (shared with
    the simulator) and the per-object reference engine assess every live
    snapshot identically — enforced action-for-action inside the
    coordinator (verify_columnar), under a fault that actually makes the
    policies fire."""
    vec_ff, _ = fault_free
    vec, reports, _ = _run("bino", script=PINNED_SCRIPTS["slow"],
                           fake_clock=True, verify_columnar=True,
                           repair_timeout=0.5)
    assert np.array_equal(vec_ff, vec)


def test_gang_restart_also_exact_but_slower(fault_free):
    vec_ff, _ = fault_free
    vec, reports, _ = _run("restart", script=PINNED_SCRIPTS["crash"],
                           fake_clock=True, restart_timeout=1.5)
    assert np.array_equal(vec_ff, vec)
    assert sum(r.restarts for r in reports) >= 1
    # the whole step re-ran: wasted microbatch executions
    assert sum(r.mb_executed for r in reports) > \
        sum(r.mb_needed for r in reports)


def test_checkpoint_restart_resumes_exactly(tmp_path, fault_free):
    vec_ff, _ = fault_free
    rt = RuntimeConfig(n_hosts=4, microbatches_per_shard=4,
                       recovery="bino", compute_delay=0.02,
                       checkpoint_dir=str(tmp_path), checkpoint_every=2)
    t1 = TrainerRuntime(CFG, TC, rt, seq_len=32, per_shard_batch=2, seed=0)
    try:
        t1.run(2)  # checkpoint at step 2
    finally:
        t1.shutdown()
    # "crash" the coordinator; a fresh trainer restores step 2 and finishes
    t2 = TrainerRuntime(CFG, TC, rt, seq_len=32, per_shard_batch=2, seed=0)
    try:
        assert t2._start_step == 2
        t2.run(1)  # step 3 (0-indexed: steps 0,1 done, now 2)
        vec = _params_vec(t2)
    finally:
        t2.shutdown()
    assert np.array_equal(vec_ff, vec)


def test_elastic_continue_with_fewer_hosts(fault_free):
    """After a permanent host loss the shards re-pack onto survivors and
    training continues (elastic scaling)."""
    vec_ff, _ = fault_free
    vec, reports, _ = _run("bino", steps=4,
                           script=PINNED_SCRIPTS["crash"], fake_clock=True,
                           repair_timeout=0.5)
    assert len(reports) == 4
    assert all(r.mb_executed >= r.mb_needed for r in reports)


def test_quorum_loss_raises_step_wedged():
    """Losing 3 of 4 hosts drops below quorum; the step rolls back, retries
    on the survivors, then surfaces StepWedged (no silent hang)."""
    script = [("crash", 1, 0.0, 0.0), ("crash", 2, 0.0, 0.0),
              ("crash", 3, 0.0, 0.0)]
    clock = FakeClock(auto_advance=True)
    chaos = ChaosController(script, horizon=HORIZON, seed=7)
    rt = RuntimeConfig(n_hosts=4, microbatches_per_shard=4,
                       recovery="bino", compute_delay=0.02,
                       step_retry_limit=1, repair_timeout=0.5,
                       step_deadline=20.0)
    t = TrainerRuntime(CFG, TC, rt, seq_len=32, per_shard_batch=2, seed=0,
                       clock=clock, chaos=chaos)
    try:
        with pytest.raises(StepWedged):
            t.run(2)
    finally:
        t.shutdown()


# ---------------------------------------------------------------------------
# Optional randomized chaos sweep: REPRO_CHAOS_EXAMPLES=N runs N extra
# random scripts (quorum-preserving kinds only) — the runtime sibling of
# the fuzz lane's REPRO_FUZZ_EXAMPLES knob.
# ---------------------------------------------------------------------------
_N_RANDOM = int(os.environ.get("REPRO_CHAOS_EXAMPLES", "0"))
_RANDOM_KINDS = ["crash_restore", "hang", "slow", "hb", "delay_hb",
                 "drop", "dup", "reorder", "cut", "part", "disk"]


@pytest.mark.parametrize("i", range(_N_RANDOM))
def test_chaos_random_scripts(fault_free, i):
    vec_ff, _ = fault_free
    rng = random.Random(1000 + i)
    script = [(rng.choice(_RANDOM_KINDS), rng.randrange(4),
               round(rng.random() * 0.5, 3), round(rng.random(), 3))
              for _ in range(rng.randrange(1, 3))]
    policy = rng.choice(["bino", "restart"])
    kw = dict(restart_timeout=1.5)
    if policy == "bino":
        kw.update(repair_timeout=0.5, verify_columnar=True)
    vec, reports, _ = _run(policy, script=script, fake_clock=True, **kw)
    assert len(reports) == 3
    assert np.array_equal(vec_ff, vec), f"script {script} diverged"


# ---------------------------------------------------------------------------
# FakeClock semantics (the anti-flake substrate itself)
# ---------------------------------------------------------------------------
def test_fake_clock_manual_advance_is_deterministic():
    clk = FakeClock(start=1000.0)
    woke = []

    def sleeper():
        clk.sleep(5.0)
        woke.append(clk.time())

    th = threading.Thread(target=sleeper, daemon=True)
    th.start()
    deadline = time.time() + 2.0
    while not clk._waiters and time.time() < deadline:
        time.sleep(0.001)
    clk.advance(4.9)
    time.sleep(0.05)
    assert not woke, "sleeper woke before its deadline"
    clk.advance(0.2)
    th.join(timeout=2.0)
    assert woke and woke[0] == pytest.approx(1005.1)
    clk.close()


def test_fake_clock_auto_advance_jumps_to_deadline():
    clk = FakeClock(start=0.0, auto_advance=True)
    t0 = time.time()
    clk.sleep(30.0)  # half a real minute, virtually
    assert time.time() - t0 < 5.0
    assert clk.time() >= 30.0
    clk.close()


def test_parse_script_named_and_inline():
    assert parse_script("crash") == PINNED_SCRIPTS["crash"]
    assert parse_script("cut:1:0.25:0.5,dup:0:0:0.9") == \
        [("cut", 1, 0.25, 0.5), ("dup", 0, 0.0, 0.9)]
