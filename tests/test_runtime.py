"""Training-runtime integration: exactly-once gradient semantics under
faults, recovery behaviour of both strategies, checkpoint/restart."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.runtime import RuntimeConfig, TrainerRuntime
from repro.train.loop import TrainConfig

CFG = reduced_config(get_config("qwen1.5-0.5b"))
TC = TrainConfig()


def _params_vec(trainer):
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(trainer.state["params"])])


def _run(recovery, steps=3, inject=None, **kw):
    rt = RuntimeConfig(n_hosts=4, microbatches_per_shard=4,
                       recovery=recovery, compute_delay=0.02, **kw)
    t = TrainerRuntime(CFG, TC, rt, seq_len=32, per_shard_batch=2, seed=0)
    try:
        reports = t.run(steps, on_step=inject)
        return _params_vec(t), reports
    finally:
        t.shutdown()


@pytest.fixture(scope="module")
def fault_free():
    return _run("bino")


def test_fault_free_full_work(fault_free):
    vec, reports = fault_free
    for r in reports:
        assert r.mb_executed >= r.mb_needed
        assert not r.recoveries
        assert np.isfinite(r.metrics["loss"])


def test_crash_recovery_exactly_once(fault_free):
    """A host crash mid-run must not change the training trajectory:
    gradients are deduped by (shard, microbatch) and summed in fixed
    order, so the final params are BIT-identical to the fault-free run."""
    vec_ff, _ = fault_free

    def inject(step, tr):
        if step == 1:
            threading.Timer(0.05, lambda: tr.freeze_host("h01")).start()

    vec, reports = _run("bino", inject=inject)
    assert any(r.recoveries for r in reports), "no recovery happened"
    assert np.array_equal(vec_ff, vec)


def test_gang_restart_also_exact_but_slower(fault_free):
    vec_ff, _ = fault_free

    def inject(step, tr):
        if step == 1:
            threading.Timer(0.05, lambda: tr.freeze_host("h01")).start()

    vec, reports = _run("restart", inject=inject, restart_timeout=2.0)
    assert np.array_equal(vec_ff, vec)
    assert sum(r.restarts for r in reports) >= 1
    # the whole step re-ran: wasted microbatch executions
    assert sum(r.mb_executed for r in reports) > \
        sum(r.mb_needed for r in reports)


def test_straggler_speculation(fault_free):
    """A 20× slowdown on one host triggers shadow execution; the run still
    matches fault-free bitwise."""
    vec_ff, _ = fault_free

    def inject(step, tr):
        if step == 1:
            tr.slow_host("h02", 20.0)

    vec, reports = _run("bino", inject=inject)
    assert np.array_equal(vec_ff, vec)
    assert any("spec" in rec or "relaunch" in rec
               for r in reports for rec in r.recoveries)


def test_checkpoint_restart_resumes_exactly(tmp_path, fault_free):
    vec_ff, _ = fault_free
    rt = RuntimeConfig(n_hosts=4, microbatches_per_shard=4,
                       recovery="bino", compute_delay=0.02,
                       checkpoint_dir=str(tmp_path), checkpoint_every=2)
    t1 = TrainerRuntime(CFG, TC, rt, seq_len=32, per_shard_batch=2, seed=0)
    try:
        t1.run(2)  # checkpoint at step 2
    finally:
        t1.shutdown()
    # "crash" the coordinator; a fresh trainer restores step 2 and finishes
    t2 = TrainerRuntime(CFG, TC, rt, seq_len=32, per_shard_batch=2, seed=0)
    try:
        assert t2._start_step == 2
        t2.run(1)  # step 3 (0-indexed: steps 0,1 done, now 2)
        vec = _params_vec(t2)
    finally:
        t2.shutdown()
    assert np.array_equal(vec_ff, vec)


def test_elastic_continue_with_fewer_hosts():
    """After a permanent host loss the shards re-pack onto survivors and
    training continues (elastic scaling)."""
    def inject(step, tr):
        if step == 0:
            threading.Timer(0.3, lambda: tr.freeze_host("h03")).start()

    vec, reports = _run("bino", steps=4, inject=inject)
    assert len(reports) == 4
    assert all(r.mb_executed >= r.mb_needed for r in reports)
