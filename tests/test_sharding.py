"""Sharding-rule resolution properties (no multi-device requirement: the
resolver is pure logic over mesh shapes)."""
import jax
import numpy as np
import pytest
# Property tests need hypothesis; a bare interpreter must still
# collect this module (tier-1 runs without the [test] extra) — the
# shared guard skips it wholesale when the extra is absent.
from conftest import require_hypothesis

require_hypothesis()
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.parallel import sharding as SH


@pytest.fixture(scope="module")
def mesh():
    # 1 real device is fine: resolution logic only reads mesh.shape names
    return make_mesh((1, 1), ("data", "model"))


def test_basic_resolution(mesh):
    spec = SH.physical_spec((128, 64), ("batch", "embed"),
                            {"batch": "data", "embed": None}, mesh)
    assert spec == P("data", None)


def test_indivisible_dim_degrades_to_replication():
    mesh = make_mesh((1,), ("model",))
    # kv_heads=1 cannot shard over a model axis of size 1? size 1 divides;
    # use a logical table mapping to a missing axis instead
    spec = SH.physical_spec((1, 64), ("kv_heads", "head_dim"),
                            {"kv_heads": "model", "head_dim": None}, mesh)
    assert spec == P("model", None) or spec == P(None, None)


def test_missing_mesh_axis_dropped(mesh):
    spec = SH.physical_spec((8, 8), ("batch", "embed"),
                            {"batch": ("pod", "data"), "embed": None}, mesh)
    # 'pod' doesn't exist on the single-pod mesh: silently dropped
    assert spec == P("data", None)


def test_axis_never_used_twice(mesh):
    spec = SH.physical_spec(
        (8, 8), ("heads", "mlp"),
        {"heads": "model", "mlp": "model"}, mesh)
    used = [s for s in spec if s is not None]
    assert used.count("model") <= 1


@given(st.integers(1, 4), st.integers(1, 4), st.data())
@settings(max_examples=30, deadline=None)
def test_spec_always_valid_for_shape(a, b, data):
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = data.draw(st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 128]),
                              min_size=2, max_size=4))
    names = data.draw(st.lists(
        st.sampled_from(["batch", "embed", "heads", "mlp", "vocab", None]),
        min_size=len(dims), max_size=len(dims)))
    spec = SH.physical_spec(tuple(dims), tuple(names), SH.ACT_RULES, mesh)
    assert len(spec) == len(dims)
    # every mapped axis divides its dimension
    for dim, s in zip(dims, spec):
        if s is None:
            continue
        axes = (s,) if isinstance(s, str) else s
        size = int(np.prod([mesh.shape[x] for x in axes]))
        assert dim % size == 0


def test_constrain_is_noop_off_mesh():
    x = jax.numpy.ones((4, 4))
    y = SH.constrain(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_use_mesh_context(mesh):
    assert SH.current_mesh() is None
    with SH.use_mesh(mesh):
        assert SH.current_mesh() is mesh
    assert SH.current_mesh() is None
