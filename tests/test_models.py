"""Per-architecture smoke tests (reduced configs on CPU): forward shapes,
no NaNs, one train step, and prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ARCH_IDS, REDUCED_SHAPE_DECODE, REDUCED_SHAPE_PREFILL,
    REDUCED_SHAPE_TRAIN, get_config, reduced_config)
from repro.models import model as MODEL
from repro.models.inputs import input_specs, materialize
from repro.train.loop import (
    TrainConfig, make_prefill_step, make_serve_step, make_train_step,
    train_state_init)

TC = TrainConfig()


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def _cfg(arch):
    return reduced_config(get_config(arch))


def test_forward_shapes_and_finite(arch):
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(0)
    params = MODEL.init_params(cfg, key)
    batch = materialize(input_specs(cfg, REDUCED_SHAPE_TRAIN), key,
                        cfg.vocab_size)
    logits, aux, _ = MODEL.forward(cfg, params, batch)
    b, s = REDUCED_SHAPE_TRAIN.global_batch, REDUCED_SHAPE_TRAIN.seq_len
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


def test_train_step_decreases_nothing_nan(arch):
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(1)
    state = train_state_init(cfg, key, TC)
    batch = materialize(input_specs(cfg, REDUCED_SHAPE_TRAIN), key,
                        cfg.vocab_size)
    step = jax.jit(make_train_step(cfg, TC))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1
    # params actually moved
    l0 = jax.tree.leaves(state["params"])[0]
    assert np.isfinite(np.asarray(l0, np.float32)).all()


def test_prefill_decode_consistency(arch):
    """Decoding token t+1 with a prefilled cache must give the same logits
    as a full forward over the extended sequence — the strongest
    correctness property of the serving path."""
    cfg = _cfg(arch)
    if cfg.is_encoder_only():
        pytest.skip("encoder-only: no decode")
    if cfg.family == "vlm":
        pytest.skip("vlm decode exercised via dense path (prefix concat)")
    key = jax.random.PRNGKey(2)
    params = MODEL.init_params(cfg, key)
    s = 16
    toks = jax.random.randint(key, (2, s + 1), 0, cfg.vocab_size, jnp.int32)

    # full forward over s+1 tokens
    logits_full, _, _ = MODEL.forward(cfg, params, {"tokens": toks})
    want = logits_full[:, -1]

    # prefill s tokens, decode the (s+1)-th
    _, cache = MODEL.prefill(cfg, params, {"tokens": toks[:, :s]},
                             max_len=s + 4)
    got, _ = MODEL.decode_step(cfg, params, cache, toks[:, s],
                               jnp.full((2,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_multi_token_decode_matches_forward(arch):
    """Greedy-decode three tokens and check each against full forwards."""
    cfg = _cfg(arch)
    if cfg.is_encoder_only() or cfg.family == "vlm":
        pytest.skip("no incremental decode path")
    if cfg.moe is not None:
        pytest.skip("MoE capacity-dropping differs between batched prefill "
                    "and single-token decode by design (token dropping)")
    key = jax.random.PRNGKey(3)
    params = MODEL.init_params(cfg, key)
    s0, extra = 8, 3
    toks = jax.random.randint(key, (1, s0 + extra), 0, cfg.vocab_size,
                              jnp.int32)
    _, cache = MODEL.prefill(cfg, params, {"tokens": toks[:, :s0]},
                             max_len=s0 + extra + 1)
    for i in range(extra):
        pos = jnp.array([s0 + i], jnp.int32)
        got, cache = MODEL.decode_step(cfg, params, cache,
                                       toks[:, s0 + i], pos)
        full, _, _ = MODEL.forward(
            cfg, params, {"tokens": toks[:, :s0 + i + 1]})
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(full[:, -1], np.float32), rtol=3e-4, atol=3e-4)


def test_param_counts_match_init(arch):
    """Analytic param_counts() equals the actual initialized tree size."""
    cfg = _cfg(arch)
    params = MODEL.init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    analytic, _ = cfg.param_counts()
    assert actual == analytic


@pytest.mark.parametrize("arch_id,total_b,active_b", [
    ("qwen1.5-0.5b", 0.46, 0.46),
    # the assignment table pins kv=32 (MHA) and d_ff 13440: 8.19B as built
    # (the HF checkpoint's nameplate 7.25B uses GQA); assignment wins.
    ("codeqwen1.5-7b", 8.19, 7.81),
    ("qwen3-8b", 8.2, 7.6),
    ("granite-20b", 20.3, 20.0),
    ("phi3.5-moe-42b-a6.6b", 41.9, 6.5),
    # assignment pins 48L (HF Moonlight uses 27): 28B total as built,
    # active 3.6B ≈ the A3B nameplate.
    ("moonshot-v1-16b-a3b", 28.1, 3.6),
    ("mamba2-2.7b", 2.7, 2.7),
    ("jamba-1.5-large-398b", 398.6, 93.7),  # nameplate 398B / 94B active
])
def test_full_config_param_counts(arch_id, total_b, active_b):
    """Full (non-reduced) configs land near their nameplate sizes (or the
    assignment-table sizes where the two differ — see comments)."""
    cfg = get_config(arch_id)
    total, active = cfg.param_counts()
    assert total / 1e9 == pytest.approx(total_b, rel=0.12), arch_id
    assert active / 1e9 == pytest.approx(active_b, rel=0.15), arch_id
