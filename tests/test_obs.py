"""Flight-recorder gates (repro.obs; DESIGN.md §18).

Four planes pinned here:

1. **Schema** — record round-trip through the structured-numpy rail and
   the parallel object rail, plus bounded memory (drop-oldest segments).
2. **Byte identity** — attaching a recorder must not change a single
   byte of simulator behaviour: obs-on vs obs-off runs are compared on
   action traces, launch sequences and job results across every shuffle
   engine (the recorder keeps its own seq counter and every emit site
   is a pure read — §18.2).
3. **Scorecard math** — precision / recall / time-to-detect / wasted
   backup work on a hand-built trace with known ground truth.
4. **Cross-world identity** — the sim and the FakeClock live runtime,
   fed the same declarative fault script, must produce scorecards with
   an identical comparable core (victims / tp / fp / fn / precision /
   recall; time-to-detect is clock-relative and waived — §18.5).
"""
import json

import numpy as np
import pytest

from conftest import assert_runs_equivalent, run_traced
from repro.obs import (
    END_COMPLETED,
    END_FAILED,
    FAULT_CODES,
    K_ACTION,
    K_ATT_END,
    K_ATT_START,
    K_DETECT,
    K_DRAIN,
    K_FAULT,
    TRACE_DTYPE,
    MetricsRegistry,
    TraceRecorder,
    comparable_core,
    instrument_drain,
    scorecard,
    to_chrome_trace,
    trace_diff,
    write_chrome_trace,
)
from repro.sim import JobSpec, faults
from repro.sim.mapreduce import Simulation

SHUFFLES = ("rescan", "event", "batch", "kernel")


# ---------------------------------------------------------------------------
# 1. Schema round-trip + bounded memory
# ---------------------------------------------------------------------------
def test_record_schema_roundtrip():
    t = [0.0]
    rec = TraceRecorder(lambda: t[0])
    t[0] = 1.5
    rec.emit(K_ATT_START, a=3, b=1, obj="t1_a0")
    t[0] = 2.25
    rec.emit(K_ATT_END, a=3, b=END_COMPLETED, f0=1.5, f1=0.75, f2=1.0,
             obj="t1_a0")
    rec.emit(K_DRAIN, b=17, f0=2.0)

    recs = rec.records()
    assert recs.dtype == TRACE_DTYPE
    assert len(rec) == 3
    assert recs["kind"].tolist() == [K_ATT_START, K_ATT_END, K_DRAIN]
    assert recs["seq"].tolist() == [0, 1, 2]
    assert recs["time"].tolist() == [1.5, 2.25, 2.25]
    end = recs[1]
    assert (int(end["a"]), int(end["b"])) == (3, END_COMPLETED)
    assert (end["f0"], end["f1"], end["f2"]) == (1.5, 0.75, 1.0)
    # object rail pairs back up in emission order; K_DRAIN carries none
    objs = [(int(r["kind"]), o) for r, o in rec.iter_with_objs()]
    assert objs == [(K_ATT_START, "t1_a0"), (K_ATT_END, "t1_a0"),
                    (K_DRAIN, None)]
    assert rec.counts() == {"attempt_start": 1, "attempt_end": 1,
                            "drain": 1}
    rec.clear()
    assert len(rec) == 0 and rec.dropped == 0


def test_ring_buffer_drops_oldest_segment():
    rec = TraceRecorder(capacity=32, segment_size=8)
    for i in range(100):
        rec.emit(K_ACTION, a=i, obj=f"act{i}")
    # bounded: at most capacity records retained, the rest counted
    assert len(rec) <= 32
    assert rec.dropped == 100 - len(rec)
    recs = rec.records()
    # newest survive, in order, seq still globally monotonic
    assert int(recs["seq"][-1]) == 99
    assert np.all(np.diff(recs["seq"]) == 1)
    assert int(recs["a"][0]) == 100 - len(rec)
    # object rail dropped with its segment: survivors still pair up
    objs = [o for _, o in rec.iter_with_objs(K_ACTION)]
    assert objs[-1] == "act99" and len(objs) == len(rec)


# ---------------------------------------------------------------------------
# 2. obs-on ≡ obs-off byte identity, per engine
# ---------------------------------------------------------------------------
OBS_SCENARIOS = [
    ("crash_during_shuffle", "bino", 3, [("crash", 7, 0.45, 0.0)]),
    ("mof_plus_slowdown", "bino", 2,
     [("mof", 0, 0.85, 1.0), ("slow", 4, 0.3, 0.2)]),
    ("yarn_crash_mid_map", "yarn", 1, [("crash", 3, 0.15, 0.0)]),
]


def _script_fault(script):
    def fault(sim, job):
        faults.apply_script(sim, job, script)
    return fault


@pytest.mark.parametrize("name,policy,seed,script",
                         OBS_SCENARIOS, ids=[s[0] for s in OBS_SCENARIOS])
def test_obs_on_off_byte_identity(name, policy, seed, script):
    """Wiring a recorder through every emit site must not move a single
    event: same action trace, same launches, same results — per engine
    (the §18.2 determinism contract)."""
    for mode in SHUFFLES:
        off = run_traced(mode, policy, _script_fault(script), seed=seed,
                         gb=1.0)
        rec = TraceRecorder()
        on = run_traced(mode, policy, _script_fault(script), seed=seed,
                        gb=1.0, obs=rec)
        assert_runs_equivalent([off, on], [f"{mode}/obs-off",
                                           f"{mode}/obs-on"])
        assert len(rec) > 0, f"{mode}: recorder saw nothing"
        assert len(rec.by_kind(K_ATT_START)) == \
            len(rec.by_kind(K_ATT_END)), mode


def test_obs_trace_is_deterministic_across_reruns():
    a, b = TraceRecorder(), TraceRecorder()
    for rec in (a, b):
        run_traced("batch", "bino",
                   _script_fault([("crash", 7, 0.45, 0.0)]),
                   seed=3, gb=1.0, obs=rec)
    d = trace_diff(a, b)
    assert d["equal"], d


def test_action_trace_lazy_and_identical():
    """Satellite 1: the unbounded repr-string list is retired — the
    ``action_trace`` property materializes lazily from the recorder's
    action rail and matches the record_actions-only private rail."""
    script = [("crash", 7, 0.45, 0.0)]
    off = run_traced("batch", "bino", _script_fault(script), seed=3, gb=1.0)
    rec = TraceRecorder()
    on = run_traced("batch", "bino", _script_fault(script), seed=3, gb=1.0,
                    obs=rec)
    assert off.sim.action_trace == on.sim.action_trace
    assert len(on.sim.action_trace) == len(rec.by_kind(K_ACTION))
    assert on.sim._act_rec is rec  # no second recorder when obs is wired


# ---------------------------------------------------------------------------
# 3. Scorecard math on hand-built ground truth
# ---------------------------------------------------------------------------
def test_scorecard_math():
    t = [0.0]
    rec = TraceRecorder(lambda: t[0])
    t[0] = 5.0
    rec.emit(K_FAULT, a=1, b=FAULT_CODES["crash"])          # victim 1
    rec.emit(K_FAULT, a=-1, b=FAULT_CODES["mof"])           # not a node
    t[0] = 6.5
    rec.emit(K_DETECT, a=1, b=1)                            # tp, ttd 1.5
    t[0] = 7.0
    rec.emit(K_DETECT, a=3, b=0)                            # fp
    t[0] = 8.0
    rec.emit(K_FAULT, a=2, b=FAULT_CODES["hang"])           # fn (missed)
    rec.emit(K_ATT_END, a=1, b=END_FAILED, f1=3.5, f2=1.0)  # wasted backup
    rec.emit(K_ATT_END, a=0, b=END_COMPLETED, f1=2.0, f2=1.0)
    rec.emit(K_ATT_END, a=0, b=END_FAILED, f1=9.0, f2=0.0)  # not a backup

    card = scorecard(rec, policy="hand")
    assert card["victims"] == [1, 2]
    assert card["tp"] == [1] and card["fp"] == [3] and card["fn"] == [2]
    assert card["precision"] == 0.5 and card["recall"] == 0.5
    assert card["ttd"] == {1: 1.5} and card["mean_ttd"] == 1.5
    assert card["n_backups"] == 2
    assert card["wasted_backup_work"] == 3.5
    assert comparable_core(card) == {
        "victims": [1, 2], "tp": [1], "fp": [3], "fn": [2],
        "precision": 0.5, "recall": 0.5}


def test_scorecard_vacuous_cases():
    rec = TraceRecorder()
    card = scorecard(rec)
    assert card["precision"] == 1.0 and card["recall"] == 1.0
    assert card["victims"] == [] and card["mean_ttd"] is None
    with pytest.raises(ValueError):
        scorecard(rec, mode="nope")


# ---------------------------------------------------------------------------
# 4. Metrics registry + instrument_drain (satellite 2)
# ---------------------------------------------------------------------------
def test_metrics_registry_snapshot():
    reg = MetricsRegistry()
    reg.counter("hits").inc()
    reg.counter("hits").inc(2)
    reg.gauge("depth").set(7.5)
    reg.histogram("lat").observe(1.0)
    reg.histogram("lat").observe(3.0)
    with reg.timer("work"):
        pass
    snap = reg.snapshot()
    assert snap["hits"] == 3 and snap["depth"] == 7.5
    assert snap["lat_n"] == 2 and snap["lat_mean"] == 2.0
    assert snap["lat_min"] == 1.0 and snap["lat_max"] == 3.0
    assert snap["work_n"] == 1 and snap["work_s"] >= 0.0


def test_instrument_drain_times_batch_lane():
    sim = Simulation(policy="bino", seed=0, n_workers=8, shuffle="batch")
    reg = instrument_drain(sim)
    sim.submit(JobSpec("j0", "terasort", 1.0))
    sim.run()
    snap = reg.snapshot()
    assert snap["drain_n"] > 0 and snap["drain_s"] > 0.0
    # rescan has no calendar lane: the timer exists but stays at zero
    sim2 = Simulation(policy="bino", seed=0, n_workers=8, shuffle="rescan")
    assert instrument_drain(sim2).snapshot() == {}


# ---------------------------------------------------------------------------
# 5. Chrome-trace export + trace diff
# ---------------------------------------------------------------------------
def test_chrome_export_roundtrip(tmp_path):
    rec = TraceRecorder()
    run_traced("batch", "bino", _script_fault([("crash", 7, 0.45, 0.0)]),
               seed=3, gb=1.0, obs=rec)
    doc = to_chrome_trace(rec)
    events = doc["traceEvents"]
    assert events, "export produced nothing"
    assert all({"name", "ph", "pid", "tid"} <= set(e) for e in events)
    # attempt lifecycle pairs become complete ("X") slices
    slices = [e for e in events if e["ph"] == "X"]
    assert slices and all(e["dur"] >= 0 for e in slices)
    assert doc["otherData"]["dropped_records"] == 0
    out = tmp_path / "trace.json"
    write_chrome_trace(rec, str(out))
    loaded = json.loads(out.read_text())
    assert len(loaded["traceEvents"]) == len(events)


def test_trace_diff_reports_divergence():
    t = [1.0]
    a, b = TraceRecorder(lambda: t[0]), TraceRecorder(lambda: t[0])
    a.emit(K_DETECT, a=1, b=1)
    b.emit(K_DETECT, a=2, b=1)
    d = trace_diff(a, b)
    assert not d["equal"] and d["first_diff"] == 0 and "a=" in d["detail"]
    assert trace_diff(a, a)["equal"]


# ---------------------------------------------------------------------------
# 6. Cross-world scorecard identity: sim vs FakeClock live runtime
# ---------------------------------------------------------------------------
CROSS_SCRIPTS = [
    [("crash", 1, 0.2, 0.0)],
    [("crash", 1, 0.2, 0.0), ("crash", 2, 0.3, 0.0)],
]


@pytest.mark.parametrize("script", CROSS_SCRIPTS,
                         ids=["one_crash", "two_crashes"])
def test_scorecard_identical_across_worlds(script):
    """The same declarative fault script, interpreted by the simulator
    and by the ChaosController against live host threads on a FakeClock,
    must yield the same detection verdict sets (§18.5). Time-to-detect
    is clock-relative and only sanity-checked per world."""
    from repro.configs import get_config, reduced_config
    from repro.runtime import (
        ChaosController,
        FakeClock,
        RuntimeConfig,
        TrainerRuntime,
    )
    from repro.train.loop import TrainConfig

    # -- sim world ----------------------------------------------------
    rec_sim = TraceRecorder()
    sim = Simulation(policy="bino", seed=1, n_workers=4, obs=rec_sim)
    job = sim.submit(JobSpec("j0", "terasort", 2.0))
    faults.apply_script(sim, job, script)
    sim.run()
    card_sim = scorecard(rec_sim, policy="bino")

    # -- live runtime world -------------------------------------------
    rec_rt = TraceRecorder(thread_safe=True)
    rt = RuntimeConfig(n_hosts=4, microbatches_per_shard=4,
                       recovery="bino", compute_delay=0.02)
    t = TrainerRuntime(
        reduced_config(get_config("qwen1.5-0.5b")), TrainConfig(), rt,
        seq_len=32, per_shard_batch=2, seed=0,
        clock=FakeClock(auto_advance=True),
        chaos=ChaosController(script, horizon=6.0, seed=7), obs=rec_rt)
    try:
        t.run(3)
        snap = t.coord.metrics.snapshot()
    finally:
        t.shutdown()
    card_rt = scorecard(rec_rt, policy="bino")

    assert comparable_core(card_sim) == comparable_core(card_rt)
    assert card_sim["recall"] == 1.0
    for card in (card_sim, card_rt):
        assert all(v > 0 for v in card["ttd"].values())
    # the coordinator's metrics plane agrees with the trace plane
    assert snap["detections"] == len(rec_rt.by_kind(K_DETECT)[
        rec_rt.by_kind(K_DETECT)["b"] == 1])
    assert snap["recoveries"] > 0
