"""Equivalence gate for the columnar assessment path (DESIGN.md §11.3).

Two halves:

1. **Action equivalence** — seeded simulations under crash / delay /
   MOF-loss faults must emit byte-identical action traces (and job
   results) whether the policies assess per-object snapshots
   (``columnar=False``, the seed reference path) or the incrementally
   maintained ``ArraySnapshot`` columns.
2. **Incremental maintenance** — mid-run, after every event type, the
   columns must equal a from-scratch rebuild from the object state
   (``Simulation.verify_arrays``).
"""
import numpy as np
import pytest

from repro.core.arrays import ArraySnapshot
from repro.sim import JobSpec, Simulation, faults


def _crash(sim, job):
    faults.crash_busiest_node_at_map_progress(sim, job, 0.4)


def _crash_restore(sim, job):
    faults.crash_busiest_node_at_map_progress(sim, job, 0.3,
                                              restore_after=90.0)


def _delay(sim, job):
    # benchmarks' delay scenario: slow the busiest node below the Eq. 3
    # threshold for a while (victim chosen at fire time).
    def fire():
        counts = {}
        for t in job.maps:
            for a in t.running_attempts():
                counts[a.node_id] = counts.get(a.node_id, 0) + 1
        victim = max(sorted(counts), key=lambda n: counts[n]) \
            if counts else sim.cluster.node_ids[0]
        sim.set_node_speed(victim, 0.05)
        sim.engine.after(150.0, sim.set_node_speed, victim, 1.0)
    sim.engine.at(30.0, fire)


def _mof(sim, job):
    faults.lose_mof_at_map_progress(sim, job, 1.0)


def _hb_outage(sim, job):
    faults.heartbeat_outage_at(sim, sim.cluster.node_ids[3], 40.0, 25.0)


def _run(policy, columnar, fault, seed=1, bench="terasort", gb=2.0,
         extra_jobs=(), verify_at=()):
    sim = Simulation(policy=policy, seed=seed, columnar=columnar,
                     record_actions=True)
    job = sim.submit(JobSpec("j0", bench, gb))
    for spec in extra_jobs:
        sim.submit(spec)
    if fault is not None:
        fault(sim, job)
    for t in verify_at:
        sim.engine.at(float(t), sim.verify_arrays)
    results = sim.run()
    return sim, results


def _assert_equivalent(policy, fault, seed=1, bench="terasort", gb=2.0,
                       extra_jobs=()):
    ref, rres = _run(policy, False, fault, seed, bench, gb, extra_jobs)
    col, cres = _run(policy, True, fault, seed, bench, gb, extra_jobs)
    assert ref.action_trace == col.action_trace
    assert [(r.job_id, r.finish_time, r.n_attempts, r.n_spec_attempts)
            for r in rres] == \
           [(r.job_id, r.finish_time, r.n_attempts, r.n_spec_attempts)
            for r in cres]
    assert col.action_trace, "scenario produced no actions — not probing"


# ---------------------------------------------------------------------------
# 1. Action-sequence equivalence on seeded faulted runs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["yarn", "bino"])
@pytest.mark.parametrize("fault,seed", [
    (_crash, 1), (_delay, 1), (_mof, 2)])
def test_actions_identical_under_faults(policy, fault, seed):
    _assert_equivalent(policy, fault, seed=seed)


def test_actions_identical_crash_restore_eq4_learning():
    # Exercises the Eq. 4 lost→resumed path (outage recording + adaptive
    # threshold) and node restore bookkeeping.
    _assert_equivalent("bino", _crash_restore, seed=3)


def test_actions_identical_heartbeat_outage():
    _assert_equivalent("bino", _hb_outage, seed=1)


def test_actions_identical_multi_job():
    extra = (JobSpec("j1", "wordcount", 1.0, submit_time=20.0),
             JobSpec("j2", "grep", 1.0, submit_time=35.0))
    _assert_equivalent("bino", _delay, seed=3, bench="aggregation",
                       extra_jobs=extra)


# ---------------------------------------------------------------------------
# 2. Incremental maintenance equals from-scratch rebuild
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy,fault", [
    ("bino", _crash_restore),   # crash, restore, rollback, kills
    ("yarn", _mof),             # MOF loss, fetch-failure recovery
    ("bino", _delay),           # speculation waves, sibling reaping
])
def test_incremental_matches_rebuild(policy, fault):
    _, results = _run(policy, True, fault, seed=1,
                      verify_at=range(10, 900, 17))
    assert results  # the faulted job still finished


def test_compaction_preserves_behavior_and_consistency():
    # Force physical compaction mid-run (normally triggered only after
    # thousands of dead rows) and require identical traces + consistency.
    extra = (JobSpec("j1", "grep", 1.0, submit_time=15.0),)
    ref, rres = _run("bino", False, _crash, 2, extra_jobs=extra)

    sim = Simulation(policy="bino", seed=2, columnar=True,
                     record_actions=True)
    job = sim.submit(JobSpec("j0", "terasort", 2.0))
    sim.submit(extra[0])
    _crash(sim, job)

    def compact_and_verify():
        sim.arrays._compact()
        sim.arrays._n_dead = 0
        sim.verify_arrays()
    for t in range(20, 600, 23):
        sim.engine.at(float(t), compact_and_verify)
    cres = sim.run()
    assert ref.action_trace == sim.action_trace
    assert [r.finish_time for r in rres] == [r.finish_time for r in cres]


# ---------------------------------------------------------------------------
# 3. ArraySnapshot unit behaviors
# ---------------------------------------------------------------------------
def test_task_segments_matches_unique():
    rng = np.random.default_rng(0)
    for _ in range(20):
        torder = np.sort(rng.integers(0, 12, size=rng.integers(0, 40)))
        starts, inv = ArraySnapshot.task_segments(torder)
        uniq, ustarts, uinv = np.unique(torder, return_index=True,
                                        return_inverse=True)
        assert np.array_equal(starts, ustarts)
        assert np.array_equal(inv, uinv)
        assert np.array_equal(torder[starts], uniq)


def test_progress_matches_object_path_continuously():
    # One seeded run; at every verification point the vectorized progress
    # projection must equal a.progress() bit-for-bit (checked inside
    # verify_arrays) — including reduce shuffle/compute mixing.
    _, results = _run("bino", True, _mof, seed=1, bench="join",
                      verify_at=range(5, 1200, 13))
    assert results
