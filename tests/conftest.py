"""Shared test plumbing (ISSUE 4 satellite).

One home for the optional-dependency guards and the simulator harness
that were copy-pasted across the suite:

- ``HAVE_HYPOTHESIS`` / ``require_hypothesis`` — tier-1 must *collect*
  on a bare interpreter (no ``[test]`` extra), so modules either gate
  individual tests (``skipif(not HAVE_HYPOTHESIS)``) or skip wholesale
  at import (``require_hypothesis()``).
- ``HAVE_JAX`` / ``require_jax`` — the jax-compat gate for the
  differential suites that cross assessment backends.
- ``run_traced`` / ``result_key`` / ``assert_runs_equivalent`` — the
  seeded, instrumented simulation harness the shuffle/columnar/fuzz
  equivalence gates share: records the speculator action trace, every
  attempt launch (time, task, node, reason, speculative, rollback), and
  the job-result key, so two configurations can be compared byte for
  byte.
- fixtures for the common cluster/job/simulation shapes.
"""
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import pytest

from repro.sim import Cluster, JobSpec, Simulation

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 must collect on a bare interpreter
    HAVE_HYPOTHESIS = False

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

skip_no_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")
skip_no_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def require_hypothesis():
    """Module-level skip for hypothesis-only test modules (the old
    per-module ``pytest.importorskip('hypothesis')`` pattern)."""
    return pytest.importorskip("hypothesis")


def require_jax():
    return pytest.importorskip("jax")


# ---------------------------------------------------------------------------
# Seeded, instrumented simulation harness
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TraceResult:
    sim: Simulation
    job: object
    launches: List[Tuple]
    results: List[object]

    @property
    def trace(self):
        return self.sim.action_trace

    def key(self):
        """Everything the equivalence gates compare, in one tuple."""
        return (self.sim.action_trace, self.launches,
                result_key(self.results))


def result_key(results) -> List[Tuple]:
    return [(r.job_id, r.finish_time, r.n_attempts, r.n_spec_attempts,
             r.n_fetch_failures) for r in results]


def run_traced(mode: str, policy: str, fault: Optional[Callable] = None,
               seed: int = 1, bench: str = "terasort", gb: float = 2.0,
               n_reduces: Optional[int] = None,
               extra_jobs: Sequence[JobSpec] = (),
               assess_backend: Optional[str] = None,
               checks: Optional[Sequence[float]] = None,
               columnar: bool = True,
               net: object = "flat", racks: int = 0,
               net_opts: Optional[dict] = None,
               generic_drain: bool = False,
               obs: object = None,
               dispatch_opts: Optional[dict] = None) -> TraceResult:
    """One seeded simulation with launch instrumentation. ``checks``
    schedules mid-run invariant sweeps (shuffle partition + registry +
    columnar mirror + network flow/link counters); ``net``/``racks``
    select the network model (DESIGN.md §15); ``generic_drain`` forces
    the batch lane's reference drain loop (parity vs the fused loop)."""
    sim = Simulation(policy=policy, seed=seed, shuffle=mode,
                     columnar=columnar, assess_backend=assess_backend,
                     net=net, racks=racks, net_opts=net_opts,
                     record_actions=True, obs=obs,
                     dispatch_opts=dispatch_opts)
    if generic_drain:
        sim.shuffle.batches._drain_impl = sim.shuffle.batches._generic_drain
    launches: List[Tuple] = []
    orig = sim._start_attempt

    def logged(req, node_id):
        launches.append((sim.engine.now, req.task.task_id, node_id,
                         req.reason, req.speculative, req.rollback))
        return orig(req, node_id)

    sim._start_attempt = logged
    job = sim.submit(JobSpec("j0", bench, gb, n_reduces=n_reduces))
    for spec in extra_jobs:
        sim.submit(spec)
    if fault is not None:
        fault(sim, job)
    if checks:
        for t in checks:
            sim.engine.at(float(t), check_invariants, sim)
    results = sim.run()
    return TraceResult(sim, job, launches, results)


def check_invariants(sim: Simulation) -> None:
    """Mid-run consistency sweep: the per-dependency status partition,
    the MOF registry vs a from-scratch recomputation, the network
    model's flow/link counters vs a live-transfer recount, and (when
    the columnar mirror is on) the incrementally-maintained columns."""
    for job in sim.active_jobs.values():
        # n_maps_done is decremented by producer re-execution (MOF loss)
        # and must never undershoot zero, even when the loss races job
        # completion (DISPATCH §19 double-enqueue audit).
        assert 0 <= job.n_maps_done <= len(job.maps), \
            (job.spec.job_id, job.n_maps_done, len(job.maps))
        for t in job.reduces:
            for a in t.running_attempts():
                sim.shuffle.verify_state(a)
        for t in job.maps:
            live = sim.shuffle.registry.live.get(t.task_id, set())
            expect = {
                nid for nid in t.output_nodes
                if sim.cluster.nodes[nid].alive
                and t.task_id in sim.cluster.nodes[nid].mofs
                and nid not in sim._marked_failed
                and nid not in sim._link_down}
            got = {nid for nid in t.output_nodes if nid in live}
            assert got == expect, (t.task_id, got, expect)
    if sim.arrays is not None:
        sim.verify_arrays()  # includes the verify_network recount
    else:
        sim.verify_network()


def assert_runs_equivalent(runs: Sequence[TraceResult],
                           labels: Sequence[str]) -> None:
    """Byte-identical action traces, attempt-launch sequences and job
    results across every configuration; failures name the first
    diverging element."""
    ref, ref_label = runs[0], labels[0]
    for other, label in zip(runs[1:], labels[1:]):
        for attr in ("trace", "launches"):
            a = getattr(ref, attr) if attr != "trace" else ref.trace
            b = getattr(other, attr) if attr != "trace" else other.trace
            assert len(a) == len(b), \
                (f"{attr} length {ref_label}={len(a)} {label}={len(b)}")
            for k, (x, y) in enumerate(zip(a, b)):
                assert x == y, \
                    f"{attr}[{k}] diverged {ref_label}={x!r} {label}={y!r}"
        assert result_key(ref.results) == result_key(other.results), \
            (ref_label, label)


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------
@pytest.fixture
def cluster20() -> Cluster:
    """The paper's testbed shape: 20 workers × 8 containers."""
    return Cluster(20, 8)


@pytest.fixture
def terasort_spec() -> JobSpec:
    return JobSpec("j0", "terasort", 2.0)


@pytest.fixture
def sim_factory():
    """Factory fixture: seeded Simulation with keyword overrides."""
    def make(policy: str = "yarn", seed: int = 0, **kw) -> Simulation:
        return Simulation(policy=policy, seed=seed, **kw)
    return make
