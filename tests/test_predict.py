"""Learned straggler prediction: dataset, training, policy (ISSUE 10;
DESIGN.md §20).

Four layers, four gates:

- **Dataset** — corpus generation is byte-deterministic from its seed
  (fixed-timestamp zip writer; two runs, one sha256), and feature
  extraction matches hand-computed values on a hand-built snapshot.
- **Training** — the jax sweep converges on a synthetic separable
  corpus and is deterministic end to end (identical metadata AND
  identical checkpoint leaves across two runs from one seed); the
  checkpoint round-trips through the numpy-only loader.
- **Policy** — protocol conformance: admission never exceeds the
  speculation budget, no nomination lands on a dead or marked node,
  the silent-window detector declares a crashed node, the untrained
  default never speculates, and ``assess`` schedules zero engine
  events (inference is pure reads inside the existing tick).
- **Equivalence** — predictor runs are byte-identical across all four
  shuffle engines and under obs-on ≡ obs-off, with mid-run columnar
  invariant sweeps (the fuzz-matrix smoke for the new policy).
"""
import hashlib
import os

import numpy as np
import pytest

from conftest import (
    HAVE_JAX,
    TraceResult,
    assert_runs_equivalent,
    check_invariants,
    skip_no_jax,
)
from repro.core.types import MarkNodeFailed, SpeculateTask
from repro.obs.trace import TraceRecorder
from repro.predict.dataset import CORPUS_RUNS, generate_corpus, load_corpus
from repro.predict.features import (
    FEATURE_NAMES,
    N_FEATURES,
    candidate_rows,
    extract_features,
)
from repro.predict.model import default_params
from repro.predict.policy import PredictorPolicy
from repro.sim import JobSpec, Simulation, faults

# Two-script subset of the pinned corpus runs: fault-free (pure
# negatives) + slow_straggler (positives — a *gradual* fault with an
# observable window; a crash ends its attempts at the fault instant, so
# under the time-aware label rule crash runs are all-negative and the
# silent-window detector, not the model, owns them).
SMALL_RUNS = (CORPUS_RUNS[0], CORPUS_RUNS[3])

CRASH_AT_20 = [("crash", 1, 0.05, 0.0)]  # fires at t = 10 + 0.05*200


def fire_params():
    """A net that scores every candidate at sigmoid(5) ≈ 0.993 — the
    always-speculate extreme for budget/filter conformance tests."""
    p = default_params()
    p["b1"] = np.full(1, 5.0)
    return p


# ---------------------------------------------------------------------------
# Dataset
# ---------------------------------------------------------------------------
def _sha(path):
    with open(path, "rb") as fh:
        return hashlib.sha256(fh.read()).hexdigest()


def test_corpus_byte_deterministic(tmp_path):
    a, b, c = (str(tmp_path / f"{n}.npz") for n in "abc")
    ma = generate_corpus(a, seed=0, runs=SMALL_RUNS)
    mb = generate_corpus(b, seed=0, runs=SMALL_RUNS)
    assert ma == mb
    assert _sha(a) == _sha(b), "same seed must produce identical bytes"
    generate_corpus(c, seed=1, runs=SMALL_RUNS)
    assert _sha(a) != _sha(c), "distinct seeds must diverge"


def test_corpus_contents(tmp_path):
    path = str(tmp_path / "c.npz")
    meta = generate_corpus(path, seed=0, runs=SMALL_RUNS)
    corpus = load_corpus(path)
    X, y = corpus["X"], corpus["y"]
    assert X.shape == (meta["n_rows"], N_FEATURES)
    assert X.dtype == np.float64 and y.dtype == np.int8
    assert list(corpus["feature_names"]) == list(FEATURE_NAMES)
    assert corpus["meta"] == meta
    # the slow run must contribute positive labels, fault-free only
    # negatives (run_idx 0 is fault_free, 1 is slow_straggler)
    assert y[corpus["run_idx"] == 0].sum() == 0
    assert y[corpus["run_idx"] == 1].sum() > 0
    # leakage rule: injected oracles never appear as features
    assert "node_speed" not in FEATURE_NAMES
    assert "rack_factor" not in FEATURE_NAMES


class FakeArr:
    """Hand-built two-node snapshot for feature-value verification."""

    def __init__(self):
        self.node_ids = ["n0", "n1"]
        self.node = np.array([0, 1, 0])
        self.start = np.array([5.0, 10.0, 12.0])
        self.kind = np.array([0, 1, 0])          # map, reduce, map
        self.spec = np.array([False, True, False])
        self.deps = np.array([0, 4, 0])
        self.fetched = np.array([0, 3, 0])
        self.sh_ready = np.array([0, 2, 0])
        self.sh_inflight = np.array([0, 1, 0])
        self.sh_fail = np.array([0.0, 1.0, 0.0])
        self.node_hb = np.array([19.5, 14.0])
        self.node_alive = np.array([True, True])
        self.node_marked = np.array([False, False])
        self.node_supp = np.array([0.0, 25.0])   # node1 suppressed at t=20
        self.node_free = np.array([2, 0])
        self.node_total = np.array([8, 8])
        self.node_flows = np.array([3.0, 5.0])
        self.node_link_up = np.array([True, False])
        self.node_rack = np.array([0, 1])
        self.rack_flows = np.array([4.0, 9.0])
        self._progress = np.array([0.5, 0.25, 0.75])

    def running_rows(self, now):
        return np.arange(3)

    def progress_at(self, now, rows):
        return self._progress[rows]


def test_extract_features_hand_computed():
    arr = FakeArr()
    X = extract_features(arr, 20.0, np.arange(3))
    assert X.shape == (3, N_FEATURES)
    # per-node ρ: node0 hosts rows 0 and 2, node1 hosts row 1
    rho0 = (0.5 / 15.0 + 0.75 / 8.0) / 2.0
    rho1 = 0.25 / 10.0
    mean_rho = (rho0 + rho1) / 2.0
    expect_row0 = [
        0.5,               # progress
        0.5 / 15.0,        # progress_rate
        15.0,              # elapsed
        0.0, 0.0,          # map, primary
        0.5,               # node_silent = 20 - 19.5
        1.0, 0.0, 0.0,     # alive, unmarked, no suppression window
        2.0 / 8.0,         # node_free_frac
        rho0, rho0 / mean_rho,
        0.0, 0.0, 0.0,     # no shuffle deps (deps clamps to 1)
        0.0,               # fail_cycles
        3.0, 1.0, 4.0,     # node_flows, link up, rack0 flows
    ]
    np.testing.assert_allclose(X[0], expect_row0, rtol=1e-12)
    expect_row1 = [
        0.25, 0.25 / 10.0, 10.0,
        1.0, 1.0,          # reduce, speculative
        6.0,               # 20 - 14
        1.0, 0.0, 1.0,     # alive, unmarked, suppression window open
        0.0,               # no free containers
        rho1, rho1 / mean_rho,
        3.0 / 4.0, 2.0 / 4.0, 1.0 / 4.0, 1.0,
        5.0, 0.0, 9.0,     # node1 flows, link down, rack1 flows
    ]
    np.testing.assert_allclose(X[1], expect_row1, rtol=1e-12)


# ---------------------------------------------------------------------------
# Policy protocol conformance
# ---------------------------------------------------------------------------
def _mid_run_snapshot(until=50.0, script=CRASH_AT_20):
    """A live columnar snapshot mid-run under the neutral yarn policy
    (which never marks nodes — the fresh PredictorPolicy under test owns
    every verdict)."""
    sim = Simulation(policy="yarn", seed=1)
    job = sim.submit(JobSpec("j0", "terasort", 2.0))
    if script:
        faults.apply_script(sim, job, script)
    sim.engine.run(until=until)
    return sim, sim._snapshot()


def test_policy_requires_columnar():
    pol = PredictorPolicy(["n0"])
    sim, snap = _mid_run_snapshot(script=[])
    bare = snap.__class__(now=snap.now, nodes=snap.nodes, tasks=snap.tasks,
                          fetch_failures=snap.fetch_failures, arrays=None)
    with pytest.raises(ValueError, match="columnar"):
        pol.assess(bare)


def test_policy_admission_bounded_and_healthy_only():
    sim, snap = _mid_run_snapshot()
    arr = snap.arrays
    pol = PredictorPolicy(sim.cluster.node_ids, fire_params(),
                          total_slots=160)
    heap_len, seq = len(sim.engine._heap), sim.engine._seq
    actions = pol.assess(snap)
    # inference is pure reads: no engine event scheduled, none consumed
    assert (len(sim.engine._heap), sim.engine._seq) == (heap_len, seq)
    specs = [a for a in actions if isinstance(a, SpeculateTask)]
    assert specs, "always-fire net must nominate someone"
    assert len(specs) <= pol.budget.capacity
    # every nominated task runs on a live, unmarked node
    pos = {nid: i for i, nid in enumerate(sim.cluster.node_ids)}
    for act in specs:
        task = sim._task(act.task_id)
        hosts = [pos[a.node_id] for a in task.running_attempts()]
        assert hosts, act.task_id
        assert all(arr.node_alive[h] and not arr.node_marked[h]
                   for h in hosts), act.task_id
    # once-per-task: a second tick re-nominates nothing
    again = [a for a in pol.assess(snap) if isinstance(a, SpeculateTask)]
    assert not again


def test_policy_detects_silent_node():
    sim, snap = _mid_run_snapshot(until=40.0)   # crash at 20 → silent 20 s
    pol = PredictorPolicy(sim.cluster.node_ids, default_params())
    marks = [a for a in pol.assess(snap) if isinstance(a, MarkNodeFailed)]
    assert [m.node_id for m in marks] == [sim.cluster.node_ids[1]]
    # declared-once latch: no duplicate verdict next tick
    assert not [a for a in pol.assess(snap)
                if isinstance(a, MarkNodeFailed)]


def test_candidate_rows_mid_run():
    sim, snap = _mid_run_snapshot()
    arr, now = snap.arrays, snap.now
    rows = candidate_rows(arr, now)
    assert len(rows)
    assert not arr.spec[rows].any()
    assert (now - arr.start[rows] >= 10.0).all()
    assert arr.node_alive[arr.node[rows]].all()
    tasks = [arr.task_ids[int(r)] for r in rows]
    assert len(tasks) == len(set(tasks)), "one candidate per task"


# ---------------------------------------------------------------------------
# Equivalence: engines × obs (fuzz-matrix smoke for the new policy)
# ---------------------------------------------------------------------------
def _run_predictor(mode, *, obs=None, params=None, script=CRASH_AT_20,
                   seed=1, checks=None):
    sim = Simulation(policy="predictor", seed=seed, shuffle=mode,
                     record_actions=True, obs=obs)
    if params is not None:
        sim.speculator.params = params
    launches = []
    orig = sim._start_attempt

    def logged(req, node_id):
        launches.append((sim.engine.now, req.task.task_id, node_id,
                         req.reason, req.speculative, req.rollback))
        return orig(req, node_id)

    sim._start_attempt = logged
    job = sim.submit(JobSpec("j0", "terasort", 2.0))
    if script:
        faults.apply_script(sim, job, script)
    if checks:
        for t in checks:
            sim.engine.at(float(t), check_invariants, sim)
    results = sim.run()
    return TraceResult(sim, job, launches, results)


def test_predictor_obs_identity():
    """obs-on ≡ obs-off byte identity under an actively-firing net, with
    mid-run columnar verification on the observed run (§18.2)."""
    base = _run_predictor("event", params=fire_params())
    observed = _run_predictor("event", params=fire_params(),
                              obs=TraceRecorder(),
                              checks=(25.0, 45.0))
    assert_runs_equivalent([base, observed], ["obs-off", "obs-on"])
    assert any(spec for (_, _, _, _, spec, _) in base.launches), \
        "fire net speculated nothing — the gate probed nothing"


def test_predictor_engine_matrix():
    """The new policy rides every shuffle engine byte-identically."""
    runs, labels = [], []
    for mode in ("rescan", "event", "batch", "kernel"):
        runs.append(_run_predictor(
            mode, params=fire_params(),
            checks=(30.0,) if mode in ("batch", "kernel") else None))
        labels.append(mode)
    assert_runs_equivalent(runs, labels)


def test_default_predictor_never_speculates():
    """Checkpoint-less fallback degenerates to reap + detection."""
    res = _run_predictor("event", script=[])
    assert not any(spec for (_, _, _, _, spec, _) in res.launches)
    assert res.results and res.results[0].n_spec_attempts == 0


# ---------------------------------------------------------------------------
# Training (jax lane)
# ---------------------------------------------------------------------------
def _synthetic_corpus(path, seed=0, n=600):
    """Separable toy corpus in the real schema: positives sit at low
    node_rho_rel and low progress_rate, like true stragglers."""
    from repro.predict.dataset import _write_npz
    import json
    rng = np.random.default_rng(seed)
    X = rng.normal(0.5, 0.2, size=(n, N_FEATURES))
    y = np.zeros(n, dtype=np.int8)
    pos = rng.random(n) < 0.2
    y[pos] = 1
    X[pos, 1] = rng.normal(0.05, 0.02, size=int(pos.sum()))
    X[~pos, 1] = rng.normal(1.0, 0.1, size=int((~pos).sum()))
    X[pos, 11] = rng.normal(0.3, 0.05, size=int(pos.sum()))
    X[~pos, 11] = rng.normal(1.0, 0.1, size=int((~pos).sum()))
    meta = {"seed": seed, "synthetic": True, "n_rows": n,
            "n_positive": int(y.sum()),
            "feature_names": list(FEATURE_NAMES)}
    _write_npz(path, {
        "X": X.astype(np.float64), "y": y,
        "run_idx": np.zeros(n, dtype=np.int32),
        "feature_names": np.array(FEATURE_NAMES),
        "meta_json": np.array([json.dumps(meta, sort_keys=True)]),
    })


@skip_no_jax
def test_training_converges_and_is_deterministic(tmp_path):
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.predict.model import (
        checkpoint_metadata,
        load_params_np,
        scores_np,
    )
    from repro.predict.train import train
    corpus = str(tmp_path / "syn.npz")
    _synthetic_corpus(corpus)
    meta_a = train(corpus, str(tmp_path / "ck_a"), seed=0, steps=150)
    meta_b = train(corpus, str(tmp_path / "ck_b"), seed=0, steps=150)
    assert meta_a == meta_b, "training must be deterministic from seed"
    assert meta_a["eval"]["precision"] >= 0.9
    assert meta_a["eval"]["recall"] >= 0.9
    # numpy-only round trip: leaves identical across the two runs, and
    # the calibrated threshold separates the synthetic classes
    pa = load_params_np(str(tmp_path / "ck_a"))
    pb = load_params_np(str(tmp_path / "ck_b"))
    assert sorted(pa) == sorted(pb)
    for k in pa:
        np.testing.assert_array_equal(pa[k], pb[k])
    thr = checkpoint_metadata(str(tmp_path / "ck_a"))["threshold"]
    data = load_corpus(corpus)
    scores = scores_np(pa, data["X"])
    hit = scores > thr
    assert (hit & (data["y"] == 1)).sum() > 0.9 * data["y"].sum()


@skip_no_jax
def test_trained_policy_loads_checkpoint(tmp_path):
    from repro.predict.train import train
    corpus = str(tmp_path / "syn.npz")
    _synthetic_corpus(corpus)
    meta = train(corpus, str(tmp_path / "ck"), seed=0, steps=150)
    pol = PredictorPolicy(["n0", "n1"])
    pol.load_checkpoint(str(tmp_path / "ck"))
    assert pol.cfg.threshold == meta["threshold"]
    assert pol.params["w0"].shape == (N_FEATURES, 16)


# ---------------------------------------------------------------------------
# Runtime coordinator: learned policies skip the reference shadow
# ---------------------------------------------------------------------------
@skip_no_jax
def test_runtime_skips_ref_shadow_for_learned_policy():
    """With ``verify_columnar=True`` a learned speculator must NOT be
    shadow-diverged against the BinocularSpeculator reference — the
    shadow is skipped (ISSUE 10 satellite; DESIGN.md §20). The default
    bino path keeps its differential shadow."""
    from repro.configs import get_config, reduced_config
    from repro.runtime import FakeClock, RuntimeConfig, TrainerRuntime
    from repro.train.loop import TrainConfig

    def factory(host_ids):
        return PredictorPolicy(host_ids, total_slots=8)

    for spec_factory, expect_shadow in ((factory, False), (None, True)):
        rt = RuntimeConfig(n_hosts=4, microbatches_per_shard=4,
                           recovery="bino", compute_delay=0.02,
                           verify_columnar=True,
                           speculator_factory=spec_factory)
        t = TrainerRuntime(reduced_config(get_config("qwen1.5-0.5b")),
                           TrainConfig(), rt, seq_len=32,
                           per_shard_batch=2, seed=0,
                           clock=FakeClock(auto_advance=True))
        try:
            assert (t.coord._ref_spec is not None) == expect_shadow
            if spec_factory is not None:
                assert isinstance(t.coord.speculator, PredictorPolicy)
            reports = t.run(2)
            assert len(reports) == 2
        finally:
            t.shutdown()
